import numpy as np
import pytest

from repro.core.forest import (ObliviousForest, evaluate,
                               train_gradient_boosting,
                               train_random_forest)


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    n = 800
    x = rng.normal(0, 1, (n, 6)).astype(np.float32)
    # labels depend on two features with noise
    y = ((x[:, 0] + 0.5 * x[:, 3] + rng.normal(0, 0.3, n)) > 0)
    return x, y.astype(np.int64)


def test_rf_learns_signal(dataset):
    x, y = dataset
    f = train_random_forest(x[:600], y[:600], 2, n_trees=24)
    pred, conf = f.predict_np(x[600:])
    acc = (pred == y[600:]).mean()
    assert acc > 0.85
    assert ((conf >= 0.5) & (conf <= 1.0)).all()


def test_gb_learns_signal(dataset):
    x, y = dataset
    f = train_gradient_boosting(x[:600], y[:600], 2, n_trees=24)
    pred, _ = f.predict_np(x[600:])
    assert (pred == y[600:]).mean() > 0.85


def test_probabilities_normalized(dataset):
    x, y = dataset
    for trainer in (train_random_forest, train_gradient_boosting):
        f = trainer(x, y, 2, n_trees=8)
        p = f.predict_proba_np(x[:50])
        np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
        assert (p >= 0).all()


def test_leaf_index_manual():
    """Hand-built depth-2 oblivious tree: verify bit-packed indexing."""
    feat_idx = np.array([[0, 1]], np.int32)
    thr = np.array([[0.5, 0.5]], np.float32)
    leaves = np.arange(4, dtype=np.float32).reshape(1, 4, 1)
    f = ObliviousForest(feat_idx, thr, leaves, "rf", 2)
    x = np.array([[0.0, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 1.0]],
                 np.float32)
    idx = f.leaf_index_np(x)[:, 0]
    np.testing.assert_array_equal(idx, [0, 1, 2, 3])


def test_multiclass(dataset):
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (600, 5)).astype(np.float32)
    y = (np.digitize(x[:, 0], [-0.6, 0.0, 0.6])).astype(np.int64)
    f = train_random_forest(x, y, 4, n_trees=24, depth=6)
    pred, _ = f.predict_np(x)
    assert (pred == y).mean() > 0.75


def test_evaluate_metrics_structure(dataset):
    x, y = dataset
    f = train_random_forest(x, y, 2, n_trees=8)
    m = evaluate(f, x, y)
    assert 0 <= m["pct_high_conf"] <= 1
    for b in m["buckets"].values():
        assert 0 <= b["recall"] <= 1
        assert 0 <= b["precision"] <= 1
