"""Per-kernel validation: shape/dtype sweeps against the pure-jnp
oracles (interpret=True executes the Pallas kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.forest import train_gradient_boosting, train_random_forest
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.forest.forest import (forest_predict_pallas,
                                         resolve_block_t)
from repro.kernels.forest.ops import forest_predict, pack_forest
from repro.kernels.forest.ref import forest_predict_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref
from repro.kernels.template.ops import criticality_scores
from repro.kernels.template.ref import criticality_scores_ref

RNG = np.random.default_rng(0)


def randn(*shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(0, 1, shape).astype(dtype))


# --- template ------------------------------------------------------------

@pytest.mark.parametrize("batch,days", [(8, 5), (130, 5), (32, 10)])
def test_template_kernel_vs_oracle(batch, days):
    series = jnp.asarray(
        RNG.uniform(0, 100, (batch, days * 48)).astype(np.float32))
    out = criticality_scores(series, block_b=8)
    ref = criticality_scores_ref(series)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-3, atol=5e-4)


def test_template_kernel_classification_agreement():
    from repro.sim.telemetry import generate_population
    pop = generate_population(200, seed=9)
    s = jnp.asarray(pop.series)
    out = np.asarray(criticality_scores(s))
    ref = np.asarray(criticality_scores_ref(s))
    assert ((out[:, 0] < 0.72) == (ref[:, 0] < 0.72)).mean() == 1.0


# --- forest ----------------------------------------------------------------

@pytest.mark.parametrize("trainer,kind", [(train_random_forest, "rf"),
                                          (train_gradient_boosting, "gb")])
@pytest.mark.parametrize("n_classes", [2, 4])
def test_forest_kernel_vs_oracle(trainer, kind, n_classes):
    x = RNG.normal(0, 1, (300, 7)).astype(np.float32)
    y = RNG.integers(0, n_classes, 300)
    y[x[:, 0] > 0] = 0
    f = trainer(x, y, n_classes, n_trees=12, depth=4)
    p_np = f.predict_proba_np(x)
    p_ref = np.asarray(forest_predict_ref(
        jnp.asarray(x), jnp.asarray(f.feat_idx),
        jnp.asarray(f.thresholds), jnp.asarray(f.leaf_values), kind))
    p_pal = np.asarray(forest_predict(f, x))
    np.testing.assert_allclose(p_ref, p_np, atol=1e-5)
    np.testing.assert_allclose(p_pal, p_np, atol=1e-5)


@pytest.fixture(scope="module")
def packed_forest():
    x = RNG.normal(0, 1, (300, 7)).astype(np.float32)
    y = RNG.integers(0, 3, 300)
    y[x[:, 0] > 0.3] = 0
    f = train_random_forest(x, y, 3, n_trees=12, depth=4)
    return f, x, f.predict_proba_np(x), pack_forest(f)


@pytest.mark.parametrize("block_b", [32, 128])
@pytest.mark.parametrize("block_t", [1, 2, 3, 4, 6, 12])
def test_forest_tiled_kernel_tile_shape_parity(packed_forest, block_b,
                                               block_t):
    """The (batch, trees) grid tiling is a pure execution-schedule
    choice: every tile shape must reproduce the untiled oracle
    bit-for-bit up to float accumulation order."""
    f, x, p_np, (gather, thr, leaf, t, d, kind) = packed_forest
    b = x.shape[0]
    pad = (-b) % block_b
    xp = jnp.asarray(np.vstack([x, np.zeros((pad, x.shape[1]),
                                            np.float32)]))
    summed = forest_predict_pallas(xp, gather, thr, leaf, t, d,
                                   block_b=block_b, block_t=block_t,
                                   interpret=True)[:b]
    np.testing.assert_allclose(np.asarray(summed) / t, p_np, atol=1e-5)


def test_resolve_block_t_clamps_to_divisor():
    assert resolve_block_t(12, None) == 12
    assert resolve_block_t(12, 48) == 12
    assert resolve_block_t(12, 5) == 4      # largest divisor <= 5
    assert resolve_block_t(12, 1) == 1
    assert resolve_block_t(7, 3) == 1       # prime ensemble degrades


# --- flash attention -------------------------------------------------------

@pytest.mark.parametrize("lq,lk,window", [
    (128, 128, None), (256, 256, 64), (64, 192, None), (100, 200, 50)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_vs_ref(lq, lk, window, dtype):
    q = randn(2, 4, lq, 32).astype(dtype)
    k = randn(2, 2, lk, 32).astype(dtype)
    v = randn(2, 2, lk, 32).astype(dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          bq=64, bk=64)
    kr = jnp.repeat(k, 2, 1)
    vr = jnp.repeat(v, 2, 1)
    ref = attention_ref(q.astype(jnp.float32), kr.astype(jnp.float32),
                        vr.astype(jnp.float32), causal=True,
                        window=window)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_non_causal():
    q, k, v = randn(1, 2, 64, 16), randn(1, 2, 96, 16), randn(1, 2, 96, 16)
    out = flash_attention(q, k, v, causal=False, bq=32, bk=32)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5)


# --- ssd -------------------------------------------------------------------

@pytest.mark.parametrize("l,chunk", [(64, 16), (96, 32), (100, 32),
                                     (128, 128)])
def test_ssd_vs_recurrence(l, chunk):
    B, H, P, N = 2, 3, 16, 8
    x = randn(B, l, H, P)
    dt = jnp.asarray(RNG.uniform(0.001, 0.2, (B, l, H)).astype(np.float32))
    a = jnp.asarray(-RNG.uniform(0.3, 2.0, H).astype(np.float32))
    bm, cm = randn(B, l, N), randn(B, l, N)
    d = randn(H)
    y = ssd(x, dt, a, bm, cm, d, chunk=chunk)
    yr, _ = ssd_ref(x, dt, a, bm, cm, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)


def test_ssd_chunk_invariance():
    """Property: the chunked dual form is exact — results must not
    depend on the chunk size."""
    B, L, H, P, N = 1, 128, 2, 8, 4
    x = randn(B, L, H, P)
    dt = jnp.asarray(RNG.uniform(0.01, 0.1, (B, L, H)).astype(np.float32))
    a = jnp.asarray(-RNG.uniform(0.5, 1.0, H).astype(np.float32))
    bm, cm = randn(B, L, N), randn(B, L, N)
    d = randn(H)
    outs = [np.asarray(ssd(x, dt, a, bm, cm, d, chunk=c))
            for c in (16, 32, 64)]
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
    np.testing.assert_allclose(outs[1], outs[2], atol=1e-4)


def test_ssd_state_decay_property():
    """With dt -> 0 the SSD is the identity-decay system: y ~ D*x."""
    B, L, H, P, N = 1, 32, 2, 8, 4
    x = randn(B, L, H, P)
    dt = jnp.full((B, L, H), 1e-8)
    a = jnp.asarray(np.full(H, -1.0, np.float32))
    bm, cm = randn(B, L, N), randn(B, L, N)
    d = jnp.asarray(np.full(H, 2.0, np.float32))
    y = np.asarray(ssd(x, dt, a, bm, cm, d, chunk=16))
    np.testing.assert_allclose(y, 2.0 * np.asarray(x), atol=1e-4)
