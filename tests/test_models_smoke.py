"""Per-architecture smoke tests (deliverable f): each assigned arch at a
REDUCED config of the same family runs one forward + train step + two
decode steps on CPU; asserts output shapes and finiteness. Also checks
prefill/decode consistency (same logits either path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import transformer as T
from repro.optim import get_optimizer

B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros((B, 8, cfg.d_model),
                                          jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.encoder_frames, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    # forward: shapes + finiteness
    hidden = T.forward(cfg, params, batch, impl="naive")
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(hidden.astype(jnp.float32)).all())

    # one train step
    opt = get_optimizer(cfg.optimizer)
    ts = jax.jit(make_train_step(cfg, impl="naive"))
    params2, opt_state, metrics = ts(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, params2))
    assert max(moved) > 0

    # two decode steps
    cache = T.init_cache(cfg, B, S)
    if cfg.family == "audio":
        cache["cross"] = T.prime_cross_cache(cfg, params, batch)
    ss = jax.jit(make_serve_step(cfg))
    tok = batch["tokens"][:, :1]
    logits, cache = ss(params, cache,
                       {"tokens": tok,
                        "cache_index": jnp.asarray(0, jnp.int32)})
    assert logits.shape == (B, cfg.vocab_size)
    logits, cache = ss(params, cache,
                       {"tokens": tok,
                        "cache_index": jnp.asarray(1, jnp.int32)})
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-2.7b",
                                  "mixtral-8x22b", "zamba2-2.7b"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced forward and step-by-step decode must produce the
    same final-position logits (cache correctness)."""
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 8)), jnp.int32)
    batch = {"tokens": toks}
    hidden = T.forward(cfg, params, batch, impl="naive")
    logits_ref = np.asarray(
        T.logits_from_hidden(cfg, params, hidden)[:, -1],
        np.float32)

    cache = T.init_cache(cfg, B, 8)
    ss = jax.jit(make_serve_step(cfg))
    logits = None
    for i in range(8):
        logits, cache = ss(params, cache,
                           {"tokens": toks[:, i:i + 1],
                            "cache_index": jnp.asarray(i, jnp.int32)})
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               logits_ref, atol=0.15, rtol=0.1)


def test_sliding_window_rolling_cache():
    """SWA decode with a rolling cache matches a full cache (window
    masking) on a short sequence."""
    cfg = get_config("mixtral-8x22b").reduced()   # window=64 reduced
    assert cfg.sliding_window == 64
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    n = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, n)), jnp.int32)
    # rolling cache (capacity == window < n would roll; here n < window
    # so both paths see everything — validates pos-buffer masking)
    cache_roll = T.init_cache(cfg, B, 96)
    ss = jax.jit(make_serve_step(cfg))
    for i in range(n):
        logits_roll, cache_roll = ss(
            params, cache_roll,
            {"tokens": toks[:, i:i + 1],
             "cache_index": jnp.asarray(i, jnp.int32)})
    hidden = T.forward(cfg, params, {"tokens": toks}, impl="naive")
    ref = np.asarray(T.logits_from_hidden(cfg, params, hidden)[:, -1],
                     np.float32)
    np.testing.assert_allclose(np.asarray(logits_roll, np.float32), ref,
                               atol=0.15, rtol=0.1)
