"""CLI round-trip coverage of `repro.launch.monitor` (DESIGN.md §14,
§17).

The module fixture runs the monitor's own ``--sim`` driver once (the
sim is the expensive part) and the tests pin the report sections, the
snapshot schema, the alerts artifact and the Prometheus text against
that single bundle; one test drives `main` end-to-end through argv."""
import json

import numpy as np
import pytest

from repro.launch import monitor
from repro.obs import Observability


@pytest.fixture(scope="module")
def sim_obs():
    return monitor._run_sim(shards=2, days=0.1, seed=4)


def test_report_has_all_pillar_sections(sim_obs):
    out = monitor.render_report(sim_obs)
    # (no "== audit" — the audit trail is a pipeline feed, and the
    # sim driver's serve backend shares only the registry)
    for section in ("== metrics ==", "== slo ==", "== quality =="):
        assert section in out
    assert "critical_throttle" in out
    assert "scored=" in out and "drift" in out
    # burn rates render per window with the threshold-style suffix
    assert "burn[" in out and "x" in out


def test_snapshot_round_trips_with_full_schema(sim_obs, tmp_path):
    p = str(tmp_path / "obs_snapshot.json")
    monitor.write_snapshot(sim_obs, p)
    with open(p) as f:
        snap = json.load(f)
    assert set(snap) == {"metrics", "spans", "audit", "slo",
                         "quality", "windows", "incidents"}
    assert snap["metrics"]["sim_placements_total"][0]["value"] > 0
    rules = snap["slo"]["rules"]
    assert set(rules) >= {"critical_throttle", "alarm_rate"}
    for s in rules.values():
        assert {"consumed", "budget", "burn_rates",
                "active", "alerts"} <= set(s)
    q = snap["quality"]
    assert q["n_scored"] > 0
    assert np.isclose(
        q["crit_accuracy"],
        np.trace(q["crit_confusion"]) / np.sum(q["crit_confusion"]))
    assert snap["windows"]["watermark"] > 0
    assert snap["incidents"]["capacity_rows"] > 0


def test_alerts_artifact_schema(sim_obs, tmp_path):
    p = str(tmp_path / "obs_alerts.json")
    monitor.write_alerts(sim_obs, p)
    with open(p) as f:
        alerts = json.load(f)
    assert set(alerts) == {"active", "rules"}
    assert isinstance(alerts["active"], list)
    # whatever fired must also show active in the rule states
    for a in alerts["active"]:
        assert alerts["rules"][a["slo"]]["active"] is True
        assert set(a) >= {"slo", "burn_rates", "consumed", "budget"}


def test_prometheus_text_contains_new_families(sim_obs):
    text = sim_obs.registry.to_prometheus()
    assert "# TYPE sim_placements_total counter" in text
    assert "slo_burn_rate" in text
    assert "quality_scored" in text


def test_main_cli_round_trip(tmp_path, capsys):
    """argv -> report on stdout + all three artifacts on disk."""
    out_p = str(tmp_path / "snap.json")
    prom_p = str(tmp_path / "metrics.prom")
    alerts_p = str(tmp_path / "alerts.json")
    monitor.main(["--sim", "--shards", "2", "--days", "0.05",
                  "--seed", "0", "--out", out_p, "--prom", prom_p,
                  "--alerts", alerts_p])
    out = capsys.readouterr().out
    assert "== metrics ==" in out and "== slo ==" in out
    for p in (out_p, prom_p, alerts_p):
        assert f"-> {p}" in out
    with open(out_p) as f:
        assert "slo" in json.load(f)
    with open(alerts_p) as f:
        assert set(json.load(f)) == {"active", "rules"}
    with open(prom_p) as f:
        assert "sim_placements_total" in f.read()


def test_main_without_sim_fails_fast(capsys):
    with pytest.raises(SystemExit):
        monitor.main(["--out", "x.json"])
    assert "--sim" in capsys.readouterr().err


def test_write_alerts_on_bare_bundle(tmp_path):
    """A bundle without the SLO pillar still writes the schema —
    empty active list, empty rules."""
    p = str(tmp_path / "alerts.json")
    monitor.write_alerts(Observability(), p)
    with open(p) as f:
        assert json.load(f) == {"active": [], "rules": {}}
