"""Fleet observability plane (repro.obs, DESIGN.md §14).

Covers the three pillars and their acceptance invariants:

  * registry / audit / tracer unit behavior (kinds, labels, rings,
    exporters);
  * metrics-on is decision-bit-identical to metrics-off, unsharded
    and sharded — the kernels gained outputs, never inputs;
  * counters reconcile against oracle totals: admits + fails ==
    arrivals (exact integers), sweep counters == the standalone
    kernel's outputs, tokens drawn − credited == the pool delta, and
    the sim exporter reproduces `SimMetrics` exactly;
  * the `SimMetrics.throttled_s` array and its legacy scalar
    properties agree with the emergency plane's level order.
"""
import json

import numpy as np
import pytest

from repro.core import features as F
from repro.core.placement import ClusterState, SchedulerPolicy
from repro.core.predictor import train_service
from repro.obs import (AuditTrail, LEVEL_NAMES, MetricsRegistry,
                       Observability, SpanTracer, record_sim_metrics)
from repro.serve import (CRIT_NUF, CRIT_UF, EmergencyConfig,
                         PlaneBundle, ResourceVector,
                         ServeConfig, ServePipeline, ShardedServeConfig,
                         ShardedServePipeline, device_state, emergency)
from repro.serve.featurizer import table_from_history
from repro.sim.telemetry import arrival_batch, generate_population

BUDGET_TIGHT = 1480.0


# -- registry ---------------------------------------------------------------
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("hits_total", help="hits")
    c.inc()
    c.inc(2.5)
    assert reg.value("hits_total") == 3.5
    with pytest.raises(ValueError):
        c.inc(-1.0)
    with pytest.raises(ValueError):
        c.inc(float("nan"))
    g = reg.gauge("level")
    g.set(4.0)
    g.dec(1.5)
    assert reg.value("level") == 2.5
    h = reg.histogram("lat_seconds", lo=1e-6, base=2.0, n_buckets=40)
    for v in (1e-6, 3e-6, 0.5, 0.5, 2.0):
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(1e-6 + 3e-6 + 3.0)
    assert h.quantile(0.5) <= 1.0       # bucket bound above the median
    assert h.quantile(1.0) >= 2.0


def test_registry_labels_and_kind_mismatch():
    reg = MetricsRegistry()
    reg.counter("rejects_total", reason="capacity").inc(3)
    reg.counter("rejects_total", reason="power").inc(1)
    assert reg.value("rejects_total", reason="capacity") == 3
    assert reg.value("rejects_total", reason="power") == 1
    assert reg.value("rejects_total", reason="tokens") == 0.0  # absent
    # same series object on re-request
    assert reg.counter("rejects_total", reason="capacity").value == 3
    with pytest.raises(TypeError):
        reg.gauge("rejects_total", reason="capacity")


def test_exporters_round_trip():
    reg = MetricsRegistry()
    reg.counter("a_total", help="a help").inc(2)
    reg.gauge("b", shard="0").set(1.5)
    reg.histogram("h_seconds").observe(0.25)
    snap = json.loads(reg.to_json())
    assert snap["a_total"][0] == {"labels": {}, "kind": "counter",
                                  "value": 2.0}
    assert snap["b"][0]["labels"] == {"shard": "0"}
    assert snap["h_seconds"][0]["count"] == 1
    text = reg.to_prometheus()
    assert "# HELP a_total a help" in text
    assert "# TYPE a_total counter" in text
    assert 'b{shard="0"} 1.5' in text
    assert "h_seconds_count 1" in text
    assert "_bucket" in text


def test_level_names_match_emergency_level_order():
    """The registry's canonical level labels index exactly like the
    emergency plane's per-level arrays — the naming-drift fix."""
    assert LEVEL_NAMES[CRIT_NUF] == "nuf"
    assert LEVEL_NAMES[CRIT_UF] == "uf"
    assert len(LEVEL_NAMES) == emergency.N_LEVELS


# -- audit trail ------------------------------------------------------------
def test_audit_ring_bounds_and_explain():
    trail = AuditTrail(capacity=8)
    for b in range(5):      # 5 batches x 4 rows = 20 >> capacity 8
        trail.record_batch(
            t=float(b), batch=b,
            servers=np.array([3, -1, -2, -3]),
            chassis=np.array([1, -1, -1, -1]), rule=2,
            cores=np.array([2.0, 4.0, 8.0, 1.0]),
            is_uf=np.array([True, False, True, False]),
            p95_eff=np.array([0.5, 0.25, 0.75, 1.0]),
            valid=np.ones(4, bool),
            conservative=np.zeros(4, bool), pool_left=7.0)
    assert trail.total_recorded == 20
    assert len(trail) == 8
    rows = trail.tail(8)
    assert list(rows["seq"]) == list(range(12, 20))
    rec = trail.explain(19)
    assert rec.outcome_name == "fail_pool_tokens"
    assert "REJECTED" in rec.describe()
    adm = trail.explain(16)
    assert adm.server == 3 and adm.chassis == 1 and adm.is_uf
    assert "server 3" in adm.describe()
    with pytest.raises(KeyError):
        trail.explain(0)        # fell out of the ring
    with pytest.raises(KeyError):
        trail.explain(20)       # never recorded
    rej = trail.rejected(4)
    assert all(r.outcome < 0 for r in rej)
    assert len(rej) == 4


def test_audit_skips_padding_rows():
    trail = AuditTrail(capacity=16)
    n = trail.record_batch(
        t=0.0, batch=0, servers=np.array([5, 7, -1]),
        chassis=np.array([0, 1, -1]), rule=0,
        cores=np.array([1.0, 2.0, 4.0]), is_uf=False,
        p95_eff=0.5, valid=np.array([True, False, True]),
        conservative=False, pool_left=float("inf"))
    assert n == 2
    rows = trail.tail(2)
    assert list(rows["slot"]) == [0, 2]
    assert list(rows["server"]) == [5, -1]


# -- tracer -----------------------------------------------------------------
def test_tracer_records_spans_and_totals():
    reg = MetricsRegistry()
    tr = SpanTracer(reg, capacity=4)
    for _ in range(6):
        with tr.span("place"):
            pass
    with tr.span("infer"):
        pass
    assert len(tr) == 4                     # ring bound
    totals = tr.totals()
    assert totals["place"][0] == 6          # histogram outlives ring
    assert totals["infer"][0] == 1
    names = set(tr.tail(4)["name"])
    assert "place" in names
    h = reg.histogram("serve_span_seconds", span="place")
    assert h.count == 6


def test_jax_profile_degrades_to_noop(tmp_path):
    tr = SpanTracer(MetricsRegistry())
    with tr.jax_profile(str(tmp_path / "trace")):
        pass                                # must never raise


# -- pipeline integration ---------------------------------------------------
@pytest.fixture(scope="module")
def obs_world():
    pop = generate_population(300, seed=1)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=12)
    cap = max(v.subscription for v in hist.vms) + 8
    table = table_from_history(hist, labels, cap)
    return svc, table, arrival_batch(arrivals)


def _loaded_state(seed=3, n_servers=48, per_chassis=12, cores=40,
                  n=260):
    rng = np.random.default_rng(seed)
    st = ClusterState(n_servers=n_servers, cores_per_server=cores,
                      chassis_of_server=np.arange(n_servers)
                      // per_chassis,
                      n_chassis=n_servers // per_chassis)
    for _ in range(n):
        srv = int(rng.integers(0, n_servers))
        c = int(rng.integers(1, 8))
        if st.free_cores[srv] >= c:
            st.place(srv, c, float(rng.uniform(0.2, 1)),
                     bool(rng.random() < 0.5))
    return st


def _first_n(batch, n):
    return type(batch)(*(getattr(batch, f)[:n]
                         for f in type(batch).__dataclass_fields__))


def _pipe(svc, table, obs=None, sharded=False, budget=None):
    planes = PlaneBundle(
        emergency=EmergencyConfig.from_model(BUDGET_TIGHT), obs=obs,
        cluster_budget=None if budget is None
        else ResourceVector(watts=budget))
    kw = dict(cores_per_server=40, blades_per_chassis=12)
    if sharded:
        return ShardedServePipeline(
            svc, table, device_state(_loaded_state()),
            config=ShardedServeConfig(batch_size=32, n_shards=4,
                                      planes=planes), **kw)
    return ServePipeline(svc, table, device_state(_loaded_state()),
                         config=ServeConfig(batch_size=32,
                                            planes=planes), **kw)


def _drive(pipe, arrivals):
    """One deterministic stream: caps, two micro-batches, departures,
    flush. Returns every `ServeResult` produced, in order."""
    out = []
    out += pipe.cap_to(0, [0, 1, 2, 3], [2200.0] * 4,
                       t=np.array([1.0, 2.0, 3.0, 4.0]))
    out += pipe.submit_to(0, _first_n(arrivals, 64),
                          t=np.arange(64, dtype=np.float64) + 10.0)
    res = [r for r in out]
    if res:
        first = res[0]
        adm = np.flatnonzero(first.server >= 0)[:6]
        out += pipe.depart_to(
            0, first.server[adm],
            np.asarray(_first_n(arrivals, 32).cores)[adm],
            first.p95_eff[adm], first.workload_type[adm] == 1,
            t=np.arange(len(adm), dtype=np.float64) + 100.0)
    tail = pipe.flush()
    if tail is not None:
        out.append(tail)
    return out


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["unsharded", "sharded"])
def test_metrics_on_is_decision_bit_identical(obs_world, sharded):
    svc, table, arrivals = obs_world
    on = _pipe(svc, table, obs=Observability.full(), sharded=sharded,
               budget=90000.0 if sharded else None)
    off = _pipe(svc, table, obs=None, sharded=sharded,
                budget=90000.0 if sharded else None)
    res_on = _drive(on, arrivals)
    res_off = _drive(off, arrivals)
    assert len(res_on) == len(res_off)
    for a, b in zip(res_on, res_off):
        assert np.array_equal(np.asarray(a.server),
                              np.asarray(b.server))
        assert np.array_equal(np.asarray(a.p95_eff),
                              np.asarray(b.p95_eff))
    # the emergency plane evolved identically too
    assert on.alarms == off.alarms


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["unsharded", "sharded"])
def test_counters_reconcile_with_decisions(obs_world, sharded):
    svc, table, arrivals = obs_world
    obs = Observability.full()
    pipe = _pipe(svc, table, obs=obs, sharded=sharded,
                 budget=90000.0 if sharded else None)
    results = _drive(pipe, arrivals)
    v = obs.registry.value
    n_arrivals = sum(len(r.server) for r in results)
    admits = sum(r.n_admitted for r in results)
    rejects = {"capacity": sum(r.n_capacity_rejected for r in results),
               "power": sum(r.n_power_rejected for r in results),
               "tokens": sum(r.n_token_rejected for r in results)}
    # exact integer reconciliation against the returned decisions
    assert v("serve_arrivals_total") == n_arrivals == 64
    assert v("serve_admits_total") == admits
    for reason, count in rejects.items():
        assert v("serve_rejects_total", reason=reason) == count
    assert (v("serve_admits_total")
            + sum(v("serve_rejects_total", reason=r)
                  for r in rejects)) == n_arrivals
    assert v("serve_batches_total") == len(results)
    assert v("serve_conservative_total") == sum(
        r.n_conservative for r in results)
    assert v("emergency_alarms_total") == pipe.alarms
    assert v("emergency_cap_windows_total") == 1
    assert v("emergency_samples_total") == 4
    # audit trail: one row per arrival, outcome codes == decisions
    assert obs.audit.total_recorded == n_arrivals
    rows = obs.audit.tail(n_arrivals)
    got = np.concatenate([np.minimum(np.asarray(r.server), 0)
                          for r in results])
    assert np.array_equal(rows["outcome"], got.astype(np.int8))
    # every admitted row names the server's real chassis
    adm = rows[rows["outcome"] == 0]
    assert (adm["chassis"] == adm["server"] // 12).all()
    # spans covered every stage
    spans = set(obs.tracer.totals())
    assert {"ingest", "merge", "featurize", "infer", "place",
            "commit"} <= spans


def test_sweep_counters_match_standalone_kernel(obs_world):
    """The fused in-scan sweep counters must agree with the standalone
    cap path's host-side sums over the same windows on an identical
    pipeline — integers exactly, watt totals to f32 accumulation
    tolerance (the scan carry adds in the state dtype)."""
    svc, table, arrivals = obs_world
    obs_fused, obs_flush = Observability(), Observability()
    fused = _pipe(svc, table, obs=obs_fused)
    flush = _pipe(svc, table, obs=obs_flush)
    caps = dict(chassis=[0, 1, 2, 3], power_w=[2200.0] * 4,
                t=np.array([1.0, 2.0, 3.0, 4.0]))
    fused.cap_to(0, caps["chassis"], caps["power_w"], t=caps["t"])
    fused.submit_to(0, _first_n(arrivals, 32),
                    t=np.arange(32, dtype=np.float64) + 10.0)
    flush.cap_to(0, caps["chassis"], caps["power_w"], t=caps["t"])
    assert flush.alarms >= 1            # property read -> standalone
    vf, vs = obs_fused.registry.value, obs_flush.registry.value
    for name in ("emergency_cap_windows_total",
                 "emergency_samples_total", "emergency_alarms_total"):
        assert vf(name) == vs(name), name
    for name in ("emergency_cut_watts_total",
                 "emergency_leftover_watts_total"):
        assert vf(name) == pytest.approx(vs(name), rel=1e-5), name
    for level in LEVEL_NAMES:
        assert vf("emergency_level_cut_watts_total", level=level) == \
            pytest.approx(vs("emergency_level_cut_watts_total",
                             level=level), rel=1e-5)
    # the achieved per-level reduction covers at least the demanded
    # cut minus what no floor could absorb (hold windows may add
    # achieved reduction with zero new demand, and p-state
    # quantization can overshoot — so >=, not ==)
    achieved = sum(vs("emergency_level_cut_watts_total", level=lv)
                   for lv in LEVEL_NAMES)
    demanded = vs("emergency_cut_watts_total")
    leftover = vs("emergency_leftover_watts_total")
    assert achieved >= demanded - leftover - 1e-3


def test_tokens_drawn_minus_credited_is_pool_delta(obs_world):
    svc, table, arrivals = obs_world
    obs = Observability()
    pipe = _pipe(svc, table, obs=obs, sharded=True, budget=90000.0)
    pool_start = pipe._pool_tokens_left()
    res = pipe.submit_to(0, _first_n(arrivals, 32),
                         t=np.arange(32, dtype=np.float64) + 10.0)
    adm = np.flatnonzero(res[0].server >= 0)[:8]
    pipe.depart_to(0, res[0].server[adm],
                   np.asarray(_first_n(arrivals, 32).cores)[adm],
                   res[0].p95_eff[adm], res[0].workload_type[adm] == 1,
                   t=np.arange(len(adm), dtype=np.float64) + 50.0)
    pipe.submit_to(0, _first_n(arrivals, 32),
                   t=np.arange(32, dtype=np.float64) + 100.0)
    pool_end = pipe._pool_tokens_left()
    v = obs.registry.value
    drawn = v("serve_tokens_drawn_total")
    credited = v("serve_tokens_credited_total")
    assert drawn > 0 and credited > 0
    # net draw == pool delta (f32 pool arithmetic on device)
    assert drawn - credited == pytest.approx(pool_start - pool_end,
                                             rel=1e-4, abs=1e-2)
    # per-shard pool gauges mirror the live pool
    gauges = sum(v("serve_pool_tokens", shard=str(i)) for i in range(4))
    assert gauges == pytest.approx(pool_end, rel=1e-6)


def test_audit_pool_left_tracks_budget(obs_world):
    svc, table, arrivals = obs_world
    obs = Observability.full()
    pipe = _pipe(svc, table, obs=obs, sharded=True, budget=90000.0)
    pipe.submit_to(0, _first_n(arrivals, 32),
                   t=np.arange(32, dtype=np.float64) + 10.0)
    rows = obs.audit.tail(32)
    assert np.isfinite(rows["pool_left"]).all()
    assert rows["pool_left"][0] == pytest.approx(
        pipe._pool_tokens_left(), rel=1e-6)


# -- sim export -------------------------------------------------------------
def test_sim_metrics_throttled_array_and_properties():
    from repro.sim.scheduler_sim import SimMetrics
    m = SimMetrics(failure_rate=0.0, empty_server_ratio=0.5,
                   chassis_score_std=0.1, server_score_std=0.2,
                   placements=10, failures=0,
                   throttled_s=np.array([30.0, 5.0]))
    assert m.nuf_throttled_s == 30.0 == m.throttled_s[CRIT_NUF]
    assert m.uf_throttled_s == 5.0 == m.throttled_s[CRIT_UF]
    # default is the all-zero per-level array
    z = SimMetrics(failure_rate=0.0, empty_server_ratio=0.0,
                   chassis_score_std=0.0, server_score_std=0.0,
                   placements=0, failures=0)
    assert z.uf_throttled_s == z.nuf_throttled_s == 0.0


def test_record_sim_metrics_schema():
    from repro.sim.scheduler_sim import SimMetrics
    reg = MetricsRegistry()
    m = SimMetrics(failure_rate=0.25, empty_server_ratio=0.5,
                   chassis_score_std=0.1, server_score_std=0.2,
                   placements=8, failures=2,
                   throttled_s=np.array([30.0, 5.0]), alarms=3,
                   migrations=1)
    record_sim_metrics(reg, m)
    assert reg.value("sim_placements_total") == 8
    assert reg.value("sim_failures_total") == 2
    assert reg.value("sim_failure_rate") == 0.25
    assert reg.value("emergency_throttled_seconds_total",
                     level="nuf") == 30.0
    assert reg.value("emergency_throttled_seconds_total",
                     level="uf") == 5.0
    assert reg.value("emergency_alarms_total") == 3
    assert reg.value("emergency_migrations_total") == 1


def test_simulate_with_obs_is_identical_and_exported():
    from repro.serve.emergency import EmergencyConfig as ECfg
    from repro.sim.scheduler_sim import (PredictionChannel,
                                         ServeBackendSpec, SimSpec,
                                         simulate)
    pol, ch = SchedulerPolicy(), PredictionChannel()
    spec = SimSpec(days=0.2, seed=4, prefill_core_ratio=0.5,
                   serve=ServeBackendSpec(
                       backend="serve-sharded", shards=2,
                       cluster_budget=ResourceVector(watts=2.0e6)),
                   emergency=ECfg.from_model(BUDGET_TIGHT))
    obs = Observability.full()
    t_on, t_off = [], []
    m_on = simulate(pol, ch, spec, trace=t_on, obs=obs)
    m_off = simulate(pol, ch, spec, trace=t_off)
    assert t_on == t_off                    # bit-identical decisions
    assert np.array_equal(m_on.throttled_s, m_off.throttled_s)
    v = obs.registry.value
    # the exporter reproduced the returned metrics exactly
    assert v("sim_placements_total") == m_on.placements
    assert v("sim_failures_total") == m_on.failures
    assert v("emergency_alarms_total") == m_on.alarms
    assert v("emergency_migrations_total") == m_on.migrations
    for i, level in enumerate(LEVEL_NAMES):
        assert v("emergency_throttled_seconds_total",
                 level=level) == m_on.throttled_s[i]
    assert v("serve_dispatch_total", kind="sharded_round") > 0
    assert {"place", "emergency"} <= set(obs.tracer.totals())


# -- monitor ----------------------------------------------------------------
def test_monitor_report_and_snapshot(tmp_path, obs_world):
    from repro.launch import monitor
    svc, table, arrivals = obs_world
    obs = Observability.full()
    pipe = _pipe(svc, table, obs=obs)
    _drive(pipe, arrivals)
    report = monitor.render_report(obs)
    assert "== metrics ==" in report
    assert "== spans ==" in report
    assert "serve_arrivals_total" in report
    assert "== audit" in report
    path = tmp_path / "snap.json"
    monitor.write_snapshot(obs, str(path))
    snap = json.loads(path.read_text())
    assert set(snap) == {"metrics", "spans", "audit", "slo",
                         "quality", "windows", "incidents"}
    assert snap["metrics"]["serve_arrivals_total"][0]["value"] == 64
    assert snap["audit"]["total_recorded"] == 64
    assert all(isinstance(r["server"], int)
               for r in snap["audit"]["tail"])
