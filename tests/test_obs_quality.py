"""Prediction-quality observability (repro.obs, DESIGN.md §17).

Covers the four new pillars and their acceptance invariants:

  * windows / quality / slo / recorder unit behavior (watermark
    alignment, confusion + calibration + PSI, multi-window burn
    gating, bounded timelines);
  * the full new-pillar bundle stays decision-bit-identical to obs
    off, unsharded and sharded — the PR 7 invariant extended;
  * the flight-recorder replay reproduces an incident window's
    placement decisions exactly on a fresh pipeline;
  * the online scorecard's high-confidence confusion reconciles with
    `core.forest.evaluate` offline scoring on the same trace;
  * the sim's measured predicted-vs-realized labels: oracle scores
    1.0 exactly, the ml channel lands near its generative knobs;
  * the `model_stale` -> conservative-ratio gate
    (`serve.adaptive.gate_ratio_on_stale`).
"""
import json
import math

import numpy as np
import pytest

from repro.core import features as F
from repro.core import forest as forest_mod
from repro.core.placement import ClusterState, SchedulerPolicy
from repro.core.predictor import train_service
from repro.obs import Observability
from repro.obs.quality import PredictionScorecard, psi
from repro.obs.recorder import FlightRecorder, replay, verify_replay
from repro.obs.slo import SLOMonitor, SLORule, default_slos
from repro.obs.windows import (FixedHistogram, RollingWindow,
                               TumblingWindow, WindowPlane)
from repro.serve import (EmergencyConfig, PlaneBundle, ResourceVector,
                         ServeConfig, ServePipeline, ShardedServeConfig,
                         ShardedServePipeline, adaptive, device_state)
from repro.serve.adaptive import AdaptiveConfig, gate_ratio_on_stale
from repro.serve.featurizer import featurize_batch, table_from_history
from repro.sim.telemetry import arrival_batch, generate_population

BUDGET_TIGHT = 1480.0


# -- windows ----------------------------------------------------------------
def test_fixed_histogram_buckets_and_quantiles():
    h = FixedHistogram(0.0, 10.0, n_bins=10)
    for v in (0.5, 1.5, 1.5, 9.9):
        h.observe(v)
    h.observe(-1.0)            # underflow
    h.observe(25.0)            # overflow
    h.observe(float("nan"))    # poisoned -> overflow, visible
    assert h.total == 7
    assert h.underflow == 1 and h.overflow == 2
    assert h.counts[0] == 1 and h.counts[1] == 2 and h.counts[9] == 1
    assert h.quantile(0.5) == pytest.approx(2.0)
    assert h.quantile(1.0) == 10.0
    snap = h.snapshot()
    assert snap["total"] == 7 and snap["underflow"] == 1
    with pytest.raises(ValueError):
        FixedHistogram(1.0, 1.0)
    with pytest.raises(ValueError):
        h.quantile(1.5)
    assert math.isnan(FixedHistogram(0, 1).quantile(0.5))


def test_tumbling_window_alignment_and_late_events():
    w = TumblingWindow(width=10.0, keep=4)
    w.observe(3.0, 1.0)
    w.observe(7.0, 3.0)
    w.observe(12.0, 5.0)
    assert w.advance(10.0) and w.last.count == 2
    assert w.last.t0 == 0.0 and w.last.t1 == 10.0
    assert w.last.sum == 4.0 and w.last.vmax == 3.0
    # an event stamped before the closed frontier is late, counted,
    # and never mutates the closed window
    w.observe(5.0, 100.0)
    assert w.late == 1 and w.last.sum == 4.0
    # watermark never moves backwards
    w.advance(1.0)
    assert w.watermark == 10.0
    closed = w.advance(40.0)
    assert [c.t0 for c in closed] == [10.0]
    assert len(w.closed) == 2


def test_rolling_window_eviction_and_rate():
    r = RollingWindow(width=10.0)
    r.observe(1.0, 2.0)
    r.observe(5.0, 3.0)
    assert r.sum == 5.0 and r.count == 2
    r.observe(12.0, 4.0)       # evicts the t=1 sample (1 <= 12 - 10)
    assert r.sum == 7.0 and r.count == 2
    assert r.rate == pytest.approx(0.7)
    r.advance(30.0)
    assert r.sum == 0.0 and r.count == 0


def test_window_plane_signals_and_registry_export():
    obs = Observability()
    plane = WindowPlane(registry=obs.registry, width=10.0, rolling=20.0)
    for t in (1.0, 2.0, 11.0):
        plane.observe(t, "alarms")
    plane.observe(11.0, "cut_watts", 250.0)
    plane.observe_hist("cut_watts", 250.0, lo=0.0, hi=1000.0)
    plane.advance(15.0)
    assert obs.registry.value("obs_window_sum", signal="alarms") == 3.0
    assert obs.registry.value("obs_window_rate_per_s",
                              signal="cut_watts") == pytest.approx(12.5)
    s = plane.summary()
    assert s["watermark"] == 15.0
    assert s["signals"]["alarms"]["last_window"]["count"] == 2
    assert s["histograms"]["cut_watts"]["total"] == 1
    json.dumps(s)              # strict JSON-ready


# -- quality ----------------------------------------------------------------
def test_psi_properties():
    assert psi([10, 10], [10, 10]) == pytest.approx(0.0, abs=1e-9)
    assert psi([0, 0], [1, 1]) == 0.0          # no data -> no drift
    shifted = psi([90, 10], [10, 90])
    assert shifted > 0.25                       # conventionally "shifted"
    assert psi([90, 10], [85, 15]) < shifted    # monotone-ish in shift
    with pytest.raises(ValueError):
        psi([1, 2], [1, 2, 3])


def test_scorecard_confusion_accuracy_and_summary():
    sc = PredictionScorecard(min_scored=4)
    sc.record(true_crit=[1, 1, 0, 0], true_bucket=[3, 2, 1, 0],
              crit_used=[1, 0, 0, 1], bucket_used=[3, 2, 0, 0])
    assert sc.n_scored == 4
    assert sc.crit_accuracy == pytest.approx(0.5)
    assert sc.p95_accuracy == pytest.approx(0.75)
    assert sc.crit.used_cm[1, 1] == 1 and sc.crit.used_cm[1, 0] == 1
    s = sc.summary()
    assert s["crit_confusion"][0][1] == 1
    assert s["model_stale"] is False            # accuracy at threshold
    json.dumps(s)


def test_scorecard_empty_summary_is_strict_json():
    s = PredictionScorecard().summary()
    assert s["crit_accuracy"] is None and s["ece"]["crit"] is None
    json.dumps(s)


def test_scorecard_drift_and_stale_verdict():
    sc = PredictionScorecard(reference_n=8, min_scored=8, stale_psi=0.25)
    # freeze a balanced reference, then feed a shifted stream
    sc.record(true_crit=[0, 1] * 4, true_bucket=[0, 1, 2, 3] * 2,
              crit_used=[0, 1] * 4, bucket_used=[0, 1, 2, 3] * 2)
    assert not sc.model_stale and sc.drift()["crit_pred"] == \
        pytest.approx(0.0, abs=1e-9)
    for _ in range(16):
        sc.record(true_crit=[1] * 4, true_bucket=[3] * 4,
                  crit_used=[1] * 4, bucket_used=[3] * 4)
    assert max(sc.drift().values()) > 0.25
    assert sc.model_stale
    assert sc.registry is None                  # no export needed
    # accuracy collapse alone also trips it
    sc2 = PredictionScorecard(min_scored=8, stale_accuracy=0.5)
    sc2.record(true_crit=[1] * 8, true_bucket=[0] * 8,
               crit_used=[0] * 8, bucket_used=[0] * 8)
    assert sc2.crit_accuracy == 0.0 and sc2.model_stale


def test_scorecard_hot_swap_resets_everything():
    sc = PredictionScorecard(reference_n=4, min_scored=2)
    sc.set_reference([5, 5], [1, 2, 3, 4], [4, 3, 2, 1])
    sc.record(true_crit=[1] * 4, true_bucket=[3] * 4,
              crit_used=[1] * 4, bucket_used=[3] * 4,
              crit_raw=[1] * 4, crit_conf=[0.9] * 4,
              bucket_raw=[3] * 4, bucket_conf=[0.8] * 4)
    sc.observe_alarms(2, cut_w=100.0, samples=4)
    assert sc.n_scored == 4 and sc.crit.n_hi == 4
    sc.on_hot_swap()
    assert sc.n_scored == 0 and sc.crit.n_hi == 0
    assert sc._ref is None and not sc._ref_frozen_explicit
    assert sc.drift() == {c: 0.0 for c in sc.drift()}
    # throttle context is fleet history, not per-model: it survives
    assert sc.alarms_seen == 2


def test_scorecard_calibration_bins_and_ece():
    sc = PredictionScorecard(n_conf_bins=10)
    sc.record(true_crit=[1, 1, 1, 0], true_bucket=[0] * 4,
              crit_used=[1, 1, 1, 0], bucket_used=[0] * 4,
              crit_raw=[1, 1, 1, 1], crit_conf=[0.95, 0.95, 0.95, 0.95],
              bucket_raw=[0] * 4, bucket_conf=[0.55] * 4)
    # crit: conf 0.95 but 3/4 correct -> ece = |0.75 - 0.95|
    assert sc.crit.ece == pytest.approx(0.2)
    # bucket raw conf 0.55 under the 0.6 gate: calibration counts it,
    # the high-confidence confusion does not
    assert sc.bucket.n_hi == 0 and sc.bucket.bin_n.sum() == 4


def test_slo_rule_validation():
    with pytest.raises(ValueError):
        SLORule("x", "m_total", budget=0.0)
    with pytest.raises(ValueError):
        SLORule("x", "m_total", budget=1.0, windows=())
    with pytest.raises(ValueError):
        SLOMonitor(rules=[SLORule("a", "m", 1.0), SLORule("a", "m", 2.0)])
    names = [r.name for r in default_slos()]
    assert "critical_throttle" in names and len(set(names)) == len(names)


def test_slo_multi_window_burn_gating():
    rule = SLORule("ct", "thr_total", budget=60.0, period_s=86400.0,
                   windows=((300.0, 14.4), (3600.0, 6.0)))
    mon = SLOMonitor(rules=[rule])
    # slow trickle: fast-window burn high for a moment is NOT enough —
    # a single 1-unit spike at t=0 then silence
    mon.ingest(0.0, "thr_total", 1.0)
    assert mon.evaluate(0.0) == []
    # sustained burn: 5 units per 60 s for an hour = 300 units/h
    # fast burn = (25/60)*(86400/300) = 120x, slow = 83x -> both fire
    for k in range(1, 61):
        mon.ingest(k * 60.0, "thr_total", 5.0)
    raised = mon.evaluate()
    assert [a["slo"] for a in raised] == ["ct"]
    assert raised[0]["burn_rates"]["300s"] > 14.4
    assert raised[0]["burn_rates"]["3600s"] > 6.0
    # rising-edge only: still firing, but not re-raised
    mon.ingest(3660.0, "thr_total", 5.0)
    assert mon.evaluate() == []
    assert [a["slo"] for a in mon.active_alerts()] == ["ct"]
    # silence long enough and the alert clears
    mon.ingest(3600.0 * 4, "thr_total", 0.0)
    assert mon.evaluate() == [] and mon.active_alerts() == []
    assert mon._state["ct"].alerts == 1


def test_slo_label_matching_and_registry_sample():
    obs = Observability()
    rules = [SLORule("uf_thr", "emergency_throttled_seconds_total",
                     labels=(("level", "uf"),), budget=60.0,
                     windows=((60.0, 1.0),)),
             SLORule("rejects", "serve_rejects_total", budget=1e4,
                     windows=((60.0, 1.0),))]
    mon = SLOMonitor(rules=rules, registry=obs.registry)
    # ingest with non-matching label is ignored by the pinned rule
    mon.ingest(1.0, "emergency_throttled_seconds_total", 99.0,
               level="nuf")
    assert mon._state["uf_thr"].cum == 0.0
    mon.ingest(2.0, "emergency_throttled_seconds_total", 7.0,
               level="uf")
    assert mon._state["uf_thr"].cum == 7.0
    # registry sample: unlabeled rule sums the whole family
    obs.registry.counter("serve_rejects_total", reason="power").inc(3)
    obs.registry.counter("serve_rejects_total", reason="tokens").inc(2)
    mon.sample(3.0, obs.registry)
    assert mon._state["rejects"].cum == 5.0
    mon.evaluate(3.0)
    assert obs.registry.value("slo_burn_rate", slo="uf_thr",
                              window="60s") > 0.0


# -- flight recorder --------------------------------------------------------
def test_recorder_bounds_eviction_and_wrapped_refusal():
    r = FlightRecorder(capacity_rows=8, incident_capacity=2)
    r.record_decision(np.arange(4), 1.0)
    r.record_decision(np.arange(4), 2.0)
    assert not r.wrapped and r.rows == 8
    r.record_decision(np.arange(4), 3.0)
    assert r.wrapped and r.dropped_runs == 1
    assert len(r.decisions()) == 8
    for k in range(3):
        r.mark_incident(float(k), alarms=k + 1)
    assert len(r.incidents) == 2               # bounded ring
    with pytest.raises(ValueError):
        replay(r, pipeline=None)
    s = r.summary()
    assert s["wrapped"] and s["by_kind"]["decision"] == 2
    json.dumps(s)
    with pytest.raises(ValueError):
        FlightRecorder(capacity_rows=0)


# -- pipeline integration ---------------------------------------------------
@pytest.fixture(scope="module")
def quality_world():
    pop = generate_population(300, seed=1)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=12)
    cap = max(v.subscription for v in hist.vms) + 8
    table = table_from_history(hist, labels, cap)
    return svc, table, arrival_batch(arrivals)


def _loaded_state(seed=3, n_servers=48, per_chassis=12, cores=40,
                  n=260):
    rng = np.random.default_rng(seed)
    st = ClusterState(n_servers=n_servers, cores_per_server=cores,
                      chassis_of_server=np.arange(n_servers)
                      // per_chassis,
                      n_chassis=n_servers // per_chassis)
    for _ in range(n):
        srv = int(rng.integers(0, n_servers))
        c = int(rng.integers(1, 8))
        if st.free_cores[srv] >= c:
            st.place(srv, c, float(rng.uniform(0.2, 1)),
                     bool(rng.random() < 0.5))
    return st


def _first_n(batch, n):
    return type(batch)(*(getattr(batch, f)[:n]
                         for f in type(batch).__dataclass_fields__))


def _pipe(svc, table, obs=None, sharded=False, budget=None,
          adaptive_cfg=None):
    planes = PlaneBundle(
        emergency=EmergencyConfig.from_model(BUDGET_TIGHT), obs=obs,
        adaptive=adaptive_cfg,
        cluster_budget=None if budget is None
        else ResourceVector(watts=budget))
    kw = dict(cores_per_server=40, blades_per_chassis=12)
    if sharded:
        return ShardedServePipeline(
            svc, table, device_state(_loaded_state()),
            config=ShardedServeConfig(batch_size=32, n_shards=4,
                                      planes=planes), **kw)
    return ServePipeline(svc, table, device_state(_loaded_state()),
                         config=ServeConfig(batch_size=32,
                                            planes=planes), **kw)


def _drive(pipe, arrivals):
    """Deterministic stream: caps (alarming), 64 arrivals, departures,
    flush — the incident-bearing trace the replay tests reconstruct."""
    out = []
    out += pipe.cap_to(0, [0, 1, 2, 3], [2200.0] * 4,
                       t=np.array([1.0, 2.0, 3.0, 4.0]))
    out += pipe.submit_to(0, _first_n(arrivals, 64),
                          t=np.arange(64, dtype=np.float64) + 10.0)
    if out:
        first = out[0]
        adm = np.flatnonzero(first.server >= 0)[:6]
        out += pipe.depart_to(
            0, first.server[adm],
            np.asarray(_first_n(arrivals, 32).cores)[adm],
            first.p95_eff[adm], first.workload_type[adm] == 1,
            t=np.arange(len(adm), dtype=np.float64) + 100.0)
    tail = pipe.flush()
    if tail is not None:
        out.append(tail)
    return out


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["unsharded", "sharded"])
def test_new_pillars_on_is_decision_bit_identical(quality_world,
                                                  sharded):
    """PR 7's invariant extended: windows + quality + slo + recorder
    all on never changes a decision, on either pipeline."""
    svc, table, arrivals = quality_world
    budget = 90000.0 if sharded else None
    on = _pipe(svc, table, obs=Observability.full(), sharded=sharded,
               budget=budget)
    off = _pipe(svc, table, obs=None, sharded=sharded, budget=budget)
    res_on, res_off = _drive(on, arrivals), _drive(off, arrivals)
    assert len(res_on) == len(res_off)
    for a, b in zip(res_on, res_off):
        assert np.array_equal(np.asarray(a.server),
                              np.asarray(b.server))
        assert np.array_equal(np.asarray(a.p95_eff),
                              np.asarray(b.p95_eff))
    assert on.alarms == off.alarms
    # and the pillars actually saw the run
    obs = on.obs
    assert obs.quality.n_scored == 64
    assert obs.windows.signals["arrivals"][1].count > 0
    assert obs.recorder.summary()["by_kind"]["decision"] >= 2
    assert obs.slo.summary()["alarm_rate"]["consumed"] == on.alarms


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["unsharded", "sharded"])
def test_flight_recorder_replay_is_decision_identical(quality_world,
                                                      sharded):
    """Acceptance: the replay harness reconstructs the incident
    window's placement decisions exactly on a fresh pipeline."""
    svc, table, arrivals = quality_world
    budget = 90000.0 if sharded else None
    live = _pipe(svc, table, obs=Observability.full(), sharded=sharded,
                 budget=budget)
    _drive(live, arrivals)
    rec = live.obs.recorder
    assert len(rec.incidents) >= 1             # the caps alarmed
    inc = rec.incidents[0]
    window = rec.incident_window(inc)
    assert any(r.kind == "capping" for r in window)
    fresh = _pipe(svc, table, obs=None, sharded=sharded, budget=budget)
    got = verify_replay(rec, fresh)
    assert np.array_equal(got, rec.decisions())
    assert len(got) == 64


def test_direct_serve_is_invisible_to_recorder(quality_world):
    svc, table, arrivals = quality_world
    pipe = _pipe(svc, table, obs=Observability.full())
    pipe.serve(_first_n(arrivals, 32))
    assert pipe.obs.recorder.summary()["by_kind"]["decision"] == 0
    # but the scorecard still scored it
    assert pipe.obs.quality.n_scored == 32


def test_online_scorecard_reconciles_with_offline_evaluate(
        quality_world):
    """Acceptance: the scorecard's high-confidence criticality
    confusion reconciles with `core.forest.evaluate` on the same
    trace — same forest, same features, same gate."""
    svc, table, arrivals = quality_world
    pipe = _pipe(svc, table, obs=Observability.full())
    batch = _first_n(arrivals, 64)
    pipe.submit_to(0, batch, t=np.arange(64, dtype=np.float64) + 1.0)
    pipe.flush()
    online = pipe.obs.quality.offline_style("crit")
    x = np.asarray(featurize_batch(table, batch, pad_to=64),
                   np.float32)
    y = np.asarray(batch.user_facing, np.int64)
    offline = forest_mod.evaluate(svc.criticality, x, y,
                                  confidence=svc.confidence_gate)
    assert online["pct_high_conf"] == pytest.approx(
        offline["pct_high_conf"])
    assert online["accuracy_high_conf"] == pytest.approx(
        offline["accuracy_high_conf"])
    for c, vals in online["buckets"].items():
        assert vals["recall"] == pytest.approx(
            offline["buckets"][c]["recall"])
        assert vals["precision"] == pytest.approx(
            offline["buckets"][c]["precision"])


def test_hot_swap_resets_scorecard(quality_world):
    svc, table, arrivals = quality_world
    pipe = _pipe(svc, table, obs=Observability.full())
    pipe.submit_to(0, _first_n(arrivals, 32),
                   t=np.arange(32, dtype=np.float64) + 1.0)
    assert pipe.obs.quality.n_scored == 32
    pipe.hot_swap(svc)
    assert pipe.obs.quality.n_scored == 0


# -- stale-model conservative gate ------------------------------------------
def test_gate_ratio_on_stale_clamps_and_passes_through():
    cfg = AdaptiveConfig(ratio_min=1.0, ratio_max=2.0)
    assert gate_ratio_on_stale(cfg, 1.7, stale=False) == \
        pytest.approx(1.7)
    assert gate_ratio_on_stale(cfg, 1.7, stale=True) == \
        pytest.approx(1.0)
    # never raises a ratio already below the floor, shape-generic
    out = gate_ratio_on_stale(cfg, np.array([0.9, 1.5]), stale=True)
    assert np.allclose(out, [0.9, 1.0])
    assert "gate_ratio_on_stale" in adaptive.__all__


def test_hold_on_stale_defaults_off_and_is_hashable():
    cfg = AdaptiveConfig()
    assert cfg.hold_on_stale is False
    hash(AdaptiveConfig(hold_on_stale=True))   # still jit-static-safe


# -- sim measured accuracy --------------------------------------------------
def test_sim_measured_accuracy_oracle_exact_ml_banded():
    from repro.sim.scheduler_sim import (PredictionChannel, SimSpec,
                                         simulate)
    spec = SimSpec(days=2.0, seed=3, deployments_per_hour=6.0)
    oracle = simulate(SchedulerPolicy(), PredictionChannel("oracle"),
                      spec)
    assert oracle.measured_crit_accuracy == 1.0
    assert oracle.measured_p95_accuracy == 1.0
    assert oracle.crit_confusion.sum() == oracle.p95_confusion.sum() > 0
    ml = simulate(SchedulerPolicy(), PredictionChannel("ml"), spec)
    # Table-III knobs: crit accuracy mixes the two recalls (0.99 UF /
    # 0.69 NUF at ~40% UF cores -> wide band), p95 lands below the
    # 0.84 knob because low-confidence fallbacks answer bucket 3
    assert 0.6 < ml.measured_crit_accuracy < 1.0
    assert 0.4 < ml.measured_p95_accuracy < 0.9
    assert ml.crit_confusion[0, 1] > 0         # NUF->UF flips happen
    # scoring consumed no randomness: decisions match a scoreless run
    # by construction (covered by the obs on/off sim identity test)


def test_sim_quality_feed_and_export(tmp_path):
    from repro.obs import record_sim_metrics
    from repro.sim.scheduler_sim import (PredictionChannel, SimSpec,
                                         simulate)
    obs = Observability.full()
    m = simulate(SchedulerPolicy(), PredictionChannel("ml"),
                 SimSpec(days=1.0, seed=5), obs=obs)
    # the live scorecard saw every scored prediction
    assert obs.quality.n_scored == m.crit_confusion.sum()
    assert obs.quality.crit_accuracy == pytest.approx(
        m.measured_crit_accuracy)
    v = obs.registry.value
    assert v("sim_pred_scored_total") == m.crit_confusion.sum()
    assert v("sim_pred_crit_accuracy") == pytest.approx(
        m.measured_crit_accuracy)
    assert v("sim_pred_p95_accuracy") == pytest.approx(
        m.measured_p95_accuracy)
    # a metrics object that never scored exports no accuracy gauges
    from repro.sim.scheduler_sim import SimMetrics
    reg2 = Observability().registry
    record_sim_metrics(reg2, SimMetrics(
        failure_rate=0.0, empty_server_ratio=0.0, chassis_score_std=0.0,
        server_score_std=0.0, placements=0, failures=0))
    assert reg2.value("sim_pred_scored_total") == 0.0


def test_sim_emergency_feeds_windows_and_slo():
    from repro.serve.emergency import EmergencyConfig as ECfg
    from repro.sim.scheduler_sim import (PredictionChannel,
                                         ServeBackendSpec, SimSpec,
                                         simulate)
    obs = Observability.full()
    m = simulate(SchedulerPolicy(), PredictionChannel(),
                 SimSpec(days=0.2, seed=4, prefill_core_ratio=0.5,
                         serve=ServeBackendSpec(
                             backend="serve-sharded", shards=2,
                             cluster_budget=ResourceVector(watts=2.0e6)),
                         emergency=ECfg.from_model(BUDGET_TIGHT)),
                 obs=obs)
    # SLO consumption mirrors the run's emergency outcome exactly
    s = obs.slo.summary()
    assert s["alarm_rate"]["consumed"] == m.alarms
    assert s["critical_throttle"]["consumed"] == pytest.approx(
        m.uf_throttled_s)
    if m.alarms:
        assert obs.windows.signals["alarms"][0].watermark > 0
