import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.oversubscription import (SCENARIOS, BudgetResult,
                                         FleetProfile, OversubConfig,
                                         compute_budget, scenario_table)
from repro.core.power_model import ServerPowerModel


@pytest.fixture(scope="module")
def fleet():
    return FleetProfile(beta=0.4, util_uf=0.65, util_nuf=0.44,
                        allocated_frac=0.85, servers_per_chassis=12,
                        model=ServerPowerModel())


def test_paper_example_walk(fleet):
    """§III-E example: 10000 draws topped by 2900, 2850, 2850 — with
    ample reduction capacity, the budget walks below the top draws while
    the event rate stays within (0.1%, 1%)."""
    rng = np.random.default_rng(0)
    draws = rng.uniform(2000, 2700, 9997)
    draws = np.concatenate([draws, [2900.0, 2850.0, 2850.0]])
    cfg = OversubConfig(emax_uf=0.001, fmin_uf=0.75,
                        emax_nuf=0.01, fmin_nuf=0.50, buffer=0.0)
    res = compute_budget(draws, 3720.0, cfg, fleet)
    assert res.budget_w < 2900.0
    assert res.uf_event_rate <= 0.001
    assert res.nuf_event_rate <= 0.01
    # capping events happened (we oversubscribed past the peak)
    assert res.uf_event_rate + res.nuf_event_rate > 0


def test_budget_monotone_in_event_tolerance(fleet):
    rng = np.random.default_rng(1)
    draws = np.concatenate([rng.uniform(2000, 2900, 50_000),
                            rng.uniform(2900, 3300, 500)])
    budgets = []
    for emax in (0.0005, 0.002, 0.008):
        cfg = OversubConfig(emax_uf=0.0, fmin_uf=1.0,
                            emax_nuf=emax, fmin_nuf=0.5, buffer=0.0)
        budgets.append(compute_budget(draws, 3720.0, cfg, fleet).budget_w)
    assert budgets[0] >= budgets[1] >= budgets[2]


def test_budget_monotone_in_frequency_floor(fleet):
    rng = np.random.default_rng(2)
    draws = np.concatenate([rng.uniform(2000, 2900, 50_000),
                            rng.uniform(3300, 3489, 40)])
    budgets = []
    for fmin in (0.9, 0.7, 0.5):
        cfg = OversubConfig(emax_uf=0.0, fmin_uf=1.0,
                            emax_nuf=0.01, fmin_nuf=fmin, buffer=0.0)
        budgets.append(compute_budget(draws, 3720.0, cfg, fleet).budget_w)
    # deeper throttling allowed => lower (more aggressive) budget
    assert budgets[0] >= budgets[1] >= budgets[2]


def test_zero_uf_tolerance_never_needs_uf_throttling(fleet):
    rng = np.random.default_rng(3)
    draws = np.concatenate([rng.uniform(2000, 2900, 20_000),
                            rng.uniform(3000, 3489, 200)])
    cfg = SCENARIOS["predictions_no_uf_impact"]
    res = compute_budget(draws, 3720.0, cfg, fleet)
    assert res.uf_event_rate == 0.0


def test_buffer_raises_budget(fleet):
    rng = np.random.default_rng(4)
    draws = rng.uniform(2000, 3400, 10_000)
    cfg0 = OversubConfig(0.001, 0.75, 0.009, 0.5, buffer=0.0)
    cfg1 = OversubConfig(0.001, 0.75, 0.009, 0.5, buffer=0.10)
    r0 = compute_budget(draws, 3720.0, cfg0, fleet)
    r1 = compute_budget(draws, 3720.0, cfg1, fleet)
    assert r1.budget_w >= r0.budget_w
    assert r1.budget_w == pytest.approx(
        min(r0.budget_pre_buffer_w * 1.10, 3720.0))


def test_savings_formula():
    r = BudgetResult(3270.0, 3270.0, 3720.0, 0.0, 0.0, 100)
    # delta = 1 - 3270/3720 = 12.096...% of 128 MW at $10/W
    assert r.savings_usd() == pytest.approx(
        (1 - 3270.0 / 3720.0) * 128e6 * 10, rel=1e-12)


def test_scenario_table_orderings(fleet):
    """The paper's qualitative orderings hold on synthetic telemetry."""
    from repro.sim.telemetry import generate_chassis_telemetry
    draws = generate_chassis_telemetry(64, 30, 3720.0, seed=5)
    rows = scenario_table(draws, 3720.0, fleet,
                          beta_internal_only=0.54,
                          beta_non_premium=0.4225)
    osub = {k: r.oversubscription for k, r in rows.items()}
    assert osub["traditional"] == 0.0
    # predictions beat the state of the art
    assert osub["predictions_all_minimal_uf_impact"] > \
        osub["state_of_the_art"]
    # restricting predictions to internal VMs costs oversubscription
    assert osub["predictions_internal_no_uf_impact"] <= \
        osub["predictions_all_no_uf_impact"] + 1e-9


def test_infeasible_highest_draw_returns_provisioned(fleet):
    """first_bad == 0: even the single highest draw cannot be capped
    within the event-rate tolerances -> no oversubscription at all."""
    draws = np.array([100.0] * 5 + [200.0])
    cfg = OversubConfig(emax_uf=0.0, fmin_uf=0.75,
                        emax_nuf=0.0, fmin_nuf=0.50, buffer=0.10)
    res = compute_budget(draws, 3720.0, cfg, fleet)
    assert res.budget_w == 3720.0
    assert res.budget_pre_buffer_w == 3720.0
    assert res.uf_event_rate == 0.0 and res.nuf_event_rate == 0.0
    assert res.oversubscription == 0.0


def test_buffer_clamped_at_provisioned_power(fleet):
    """Step 5 never raises the budget past the provisioned power."""
    rng = np.random.default_rng(7)
    draws = rng.uniform(2000, 2900, 5000)
    cfg = OversubConfig(0.001, 0.75, 0.01, 0.5, buffer=1.0)  # +100 %
    res = compute_budget(draws, 3000.0, cfg, fleet)
    assert res.budget_pre_buffer_w < 2900.0
    assert res.budget_w == 3000.0                 # clamped
    assert res.oversubscription == 0.0


def test_full_server_parity_with_exclusive_counting():
    """When the fleet is all-UF (red_NUF = 0) and both floors match,
    exclusive event counting degenerates to the pooled full-server
    rule: every event is a UF event and the combined tolerance binds.
    Both paths must then pick the identical budget on a shared draw
    set."""
    rng = np.random.default_rng(8)
    draws = np.concatenate([rng.uniform(2000, 3000, 20_000),
                            rng.uniform(3000, 3400, 120)])
    all_uf = FleetProfile(beta=1.0, util_uf=0.65, util_nuf=0.44,
                          allocated_frac=0.85, servers_per_chassis=12,
                          model=ServerPowerModel())
    cfg = OversubConfig(emax_uf=0.004, fmin_uf=0.60,
                        emax_nuf=0.0, fmin_nuf=0.60, buffer=0.0)
    excl = compute_budget(draws, 3720.0, cfg, all_uf)
    full = compute_budget(draws, 3720.0, cfg, all_uf, full_server=True)
    assert full.budget_w == pytest.approx(excl.budget_w)
    assert full.uf_event_rate == pytest.approx(excl.uf_event_rate)
    assert excl.nuf_event_rate == 0.0 and full.nuf_event_rate == 0.0


@given(st.integers(0, 1000))
def test_budget_never_exceeds_provisioned(seed):
    rng = np.random.default_rng(seed)
    draws = rng.uniform(1000, 3500, 2000)
    fleet = FleetProfile(beta=0.4, util_uf=0.65, util_nuf=0.44,
                         allocated_frac=0.85, servers_per_chassis=12,
                         model=ServerPowerModel())
    cfg = OversubConfig(0.001, 0.75, 0.009, 0.5)
    res = compute_budget(draws, 3720.0, cfg, fleet)
    assert res.budget_w <= 3720.0 + 1e-9
    assert 0.0 <= res.oversubscription <= 1.0
