import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.placement import (ALPHA_DEFAULT, ClusterState,
                                  SchedulerPolicy, _score_chassis_scalar,
                                  _score_server_scalar)


def make_state(n_servers=12, per_chassis=4, cores=40):
    return ClusterState(
        n_servers=n_servers, cores_per_server=cores,
        chassis_of_server=np.arange(n_servers) // per_chassis,
        n_chassis=n_servers // per_chassis)


def test_vectorized_matches_scalar_oracle():
    rng = np.random.default_rng(0)
    st_ = make_state()
    for _ in range(60):
        srv = int(rng.integers(0, 12))
        cores = int(rng.integers(1, 8))
        if st_.free_cores[srv] < cores:
            continue
        st_.place(srv, cores, float(rng.uniform(0, 1)),
                  bool(rng.random() < 0.5))
    kappa = st_.score_chassis()
    for c in range(st_.n_chassis):
        assert kappa[c] == pytest.approx(_score_chassis_scalar(st_, c))
    for uf in (True, False):
        eta = st_.score_server(uf)
        for s in range(st_.n_servers):
            assert eta[s] == pytest.approx(
                _score_server_scalar(st_, s, uf))


def test_score_reversal_between_types():
    st_ = make_state()
    st_.place(0, 10, 0.8, False)        # NUF load on server 0
    eta_uf = st_.score_server(True)
    eta_nuf = st_.score_server(False)
    # a UF VM prefers the NUF-loaded server; an NUF VM avoids it
    assert eta_uf[0] > eta_uf[1]
    assert eta_nuf[0] < eta_nuf[1]
    # reversal identity: eta_uf + eta_nuf == 1
    np.testing.assert_allclose(eta_uf + eta_nuf, 1.0)


@given(st.integers(0, 2**31 - 1))
def test_scores_in_unit_interval(seed):
    rng = np.random.default_rng(seed)
    st_ = make_state()
    for _ in range(30):
        srv = int(rng.integers(0, 12))
        cores = int(rng.integers(1, 6))
        if st_.free_cores[srv] < cores:
            continue
        st_.place(srv, cores, float(rng.uniform(0, 1)),
                  bool(rng.random() < 0.5))
    kappa = st_.score_chassis()
    assert ((kappa >= 0) & (kappa <= 1)).all()
    for uf in (True, False):
        eta = st_.score_server(uf)
        assert ((eta >= 0) & (eta <= 1)).all()
    sc = st_.score_candidates(True, np.arange(12), ALPHA_DEFAULT)
    assert ((sc >= 0) & (sc <= 1)).all()


def test_place_remove_roundtrip():
    st_ = make_state()
    before = (st_.free_cores.copy(), st_.gamma_uf.copy(),
              st_.rho_peak.copy())
    st_.place(3, 8, 0.7, True)
    st_.remove(3, 8, 0.7, True)
    np.testing.assert_allclose(st_.free_cores, before[0])
    np.testing.assert_allclose(st_.gamma_uf, before[1])
    np.testing.assert_allclose(st_.rho_peak, before[2])


def test_constraint_rule_blocks_full_servers():
    st_ = make_state()
    st_.place(0, 40, 0.5, True)
    assert 0 not in st_.feasible(1)
    pol = SchedulerPolicy()
    chosen = pol.choose(st_, 1, True)
    assert chosen != 0


def test_deployment_failure_when_no_capacity():
    st_ = make_state(n_servers=2, per_chassis=2, cores=4)
    st_.place(0, 4, 0.5, True)
    st_.place(1, 4, 0.5, False)
    pol = SchedulerPolicy()
    assert pol.choose(st_, 1, True) is None


def test_chassis_balancing_preference():
    st_ = make_state()
    # chassis 0 heavily loaded
    for srv in range(4):
        st_.place(srv, 20, 0.9, True)
    pol = SchedulerPolicy(alpha=1.0, packing_weight=0.0)
    chosen = pol.choose(st_, 4, True)
    assert st_.chassis_of_server[chosen] != 0


def test_no_utilization_predictions_uses_conservative_p95():
    pol = SchedulerPolicy(use_utilization_predictions=False)
    assert pol.effective_p95(0.25) == 1.0
    pol2 = SchedulerPolicy()
    assert pol2.effective_p95(0.25) == 0.25
