import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import features as F
from repro.core.criticality import classify
from repro.core.predictor import (UF, bucket_to_p95, table3_metrics,
                                  train_service)
from repro.sim.telemetry import generate_population


@pytest.fixture(scope="module")
def trained():
    pop = generate_population(1200, seed=21)
    hist, arr = F.split_history_arrivals(pop)
    hist_labels = np.asarray(classify(jnp.asarray(hist.series)))
    aggs = F.subscription_aggregates(hist, hist_labels)
    x = F.build_features(arr, aggs)
    y_uf = np.asarray(classify(jnp.asarray(arr.series))).astype(np.int64)
    y_p95 = F.p95_bucket(np.array([v.p95_util for v in arr.vms]))
    svc = train_service(x[:400], y_uf[:400], y_p95[:400], model="rf",
                        n_trees=16)
    return svc, x[400:], y_uf[400:], y_p95[400:]


def test_query_interface(trained):
    svc, x, y_uf, y_p95 = trained
    out = svc.query(x[:32])
    assert out["workload_type"].shape == (32,)
    assert set(np.unique(out["workload_type_used"])) <= {0, 1}
    # low-confidence falls back to conservative UF / bucket 3
    low = out["workload_conf"] < svc.confidence_gate
    assert (out["workload_type_used"][low] == UF).all()
    lowp = out["p95_conf"] < svc.confidence_gate
    assert (out["p95_bucket_used"][lowp] == 3).all()


def test_criticality_accuracy(trained):
    svc, x, y_uf, y_p95 = trained
    m = table3_metrics(svc, x, y_uf, y_p95)
    assert m["criticality"]["accuracy_high_conf"] > 0.8
    assert m["criticality"]["buckets"][1]["recall"] > 0.8


def test_p95_two_stage_predicts(trained):
    svc, x, y_uf, y_p95 = trained
    bucket, conf = svc.p95.predict(x)
    assert set(np.unique(bucket)) <= {0, 1, 2, 3}
    hi = conf >= 0.6
    if hi.sum() > 20:
        assert (bucket[hi] == y_p95[hi]).mean() > 0.5


def test_bucket_midpoints():
    np.testing.assert_allclose(bucket_to_p95(np.arange(4)),
                               [0.125, 0.375, 0.625, 0.875])
