"""Paper §V production lessons: prioritized throttling list + VM kill."""
from repro.core.power_model import F_MAX, F_MIN, ServerPowerModel
from repro.core.priority import PrioritizedVM, Tier, TieredController


def make_controller(budget=240.0, enable_kill=True):
    c = TieredController(ServerPowerModel(), budget,
                         enable_kill=enable_kill)
    c.register(PrioritizedVM("spot", 8, Tier.LOW_PRIORITY))
    c.register(PrioritizedVM("internal-batch", 10, Tier.INTERNAL_NUF))
    c.register(PrioritizedVM("ext-batch", 10, Tier.EXTERNAL_NUF))
    c.register(PrioritizedVM("frontend", 12, Tier.USER_FACING))
    return c


UTILS = {"spot": 1.0, "internal-batch": 1.0, "ext-batch": 1.0,
         "frontend": 0.7}


def test_throttling_order_follows_tiers():
    c = make_controller(budget=260.0, enable_kill=False)
    for _ in range(60):
        c.step(UTILS)
    vms = {v.name: v for v in c.vms}
    # lower tiers throttled at least as deep as higher tiers
    assert vms["spot"].freq <= vms["internal-batch"].freq
    assert vms["internal-batch"].freq <= vms["ext-batch"].freq
    assert vms["frontend"].freq == F_MAX          # never touched in-band


def test_budget_enforced():
    c = make_controller(budget=240.0, enable_kill=False)
    out = None
    for _ in range(200):
        out = c.step(UTILS)
    assert out["power_w"] <= 240.0 + 1e-6


def test_kill_preferred_vm_shed_before_throttling_tier():
    c = TieredController(ServerPowerModel(), 220.0)
    c.register(PrioritizedVM("shreddable", 10, Tier.LOW_PRIORITY,
                             kill_preferred=True))
    c.register(PrioritizedVM("batch", 20, Tier.INTERNAL_NUF))
    c.register(PrioritizedVM("frontend", 10, Tier.USER_FACING))
    out = c.step({"shreddable": 1.0, "batch": 1.0, "frontend": 0.8})
    assert "shreddable" in out["killed"]
    vms = {v.name: v for v in c.vms}
    assert not vms["shreddable"].alive


def test_recovery_raises_highest_tier_first():
    c = make_controller(budget=250.0, enable_kill=False)
    for _ in range(80):
        c.step(UTILS)                       # drive down
    low = {k: 0.15 for k in UTILS}          # load drops
    for _ in range(3):
        c.step(low)
    vms = {v.name: v for v in c.vms}
    # external batch recovers before spot
    assert vms["ext-batch"].freq >= vms["spot"].freq


def test_impact_report_structure():
    c = make_controller()
    c.step(UTILS)
    rep = c.impact_report()
    assert set(rep) == {"spot", "internal-batch", "ext-batch",
                        "frontend"}
    for v in rep.values():
        assert F_MIN <= v["freq"] <= F_MAX
