"""Property-based hardening of the serve-plane invariants.

Three families, each against an independent oracle, driven by
hypothesis (the real package when installed, else the deterministic
stub `tests/_hypothesis_stub.py` — the suite must pass under both):

  * `serve.ingest.kway_merge` == an ``np.lexsort`` of the
    concatenated ``(t, host, seq)`` keys, for ragged per-host streams
    with duplicates and empty hosts;
  * the scatter-free rank-maintenance permutation
    (`serve.placement._compose_inverse`) stays a valid bijection and
    equals a literal delete-then-insert list oracle; end to end, the
    incrementally-maintained order keeps reproducing the from-scratch
    sequential rule under arrival/departure/migration interleavings;
  * the sharded power-token pools conserve through randomized
    cap -> arrive -> depart -> adapt sequences: free pools never go
    negative, committed rho is never revoked by a controller
    back-off, and each adaptive retarget lands exactly on
    ``max(base * ratio - committed, 0)``.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.placement import ClusterState, SchedulerPolicy
from repro.serve import (AdaptiveConfig, EmergencyConfig, PlaneBundle,
                         ResourceVector,
                         ShardedServeConfig, ShardedServePipeline,
                         device_state, kway_merge, place_batch,
                         remove_batch)
from repro.serve.placement import _compose_inverse

# --- kway_merge vs the lexsort oracle -------------------------------------


@st.composite
def ragged_streams(draw):
    """1-5 hosts, each a sorted stamp array of 0-12 events drawn from
    a small value set (cross-host duplicates are likely — exactly the
    tie territory the merge contract pins down)."""
    n_hosts = draw(st.integers(min_value=1, max_value=5))
    streams = []
    for _ in range(n_hosts):
        vals = draw(st.lists(st.integers(min_value=0, max_value=30),
                             min_size=0, max_size=12))
        streams.append(np.sort(np.asarray(vals, np.float64)) * 0.5)
    return streams


@settings(max_examples=50, deadline=None)
@given(ragged_streams())
def test_kway_merge_matches_lexsort_oracle(streams):
    host, idx = kway_merge(streams)
    ts = np.concatenate(streams) if streams else np.empty(0)
    hosts = np.concatenate([np.full(len(s), h, np.int32)
                            for h, s in enumerate(streams)])
    seqs = np.concatenate([np.arange(len(s), dtype=np.int64)
                           for s in streams])
    order = np.lexsort((seqs, hosts, ts))
    np.testing.assert_array_equal(np.asarray(host), hosts[order])
    np.testing.assert_array_equal(np.asarray(idx), seqs[order])


# --- rank-maintenance permutation bijection -------------------------------


def _compose_oracle(perm_row, fresh_row, dold_row, delta):
    """Literal delete-then-insert: drop the moved servers from their
    vacated positions, pin them at their landing positions, stream
    the survivors (old relative order) through the gaps."""
    vacated = set(int(p) for p in dold_row)
    survivors = [s for pos, s in enumerate(perm_row)
                 if pos not in vacated]
    out = [-1] * len(perm_row)
    for f, d in zip(fresh_row, delta):
        out[int(f)] = int(d)
    it = iter(survivors)
    for q in range(len(out)):
        if out[q] < 0:
            out[q] = int(next(it))
    return out


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_compose_inverse_is_the_delete_insert_bijection(seed):
    rng = np.random.default_rng(seed)
    S = int(rng.integers(4, 25))
    K = int(rng.integers(1, min(S, 7)))
    R = int(rng.integers(1, 4))
    perm = np.stack([rng.permutation(S) for _ in range(R)]) \
        .astype(np.int32)
    delta = rng.choice(S, K, replace=False).astype(np.int32)
    pos_of = np.argsort(perm, axis=-1)                  # server -> pos
    d_old = pos_of[:, delta].astype(np.int32)
    fresh = np.stack([rng.choice(S, K, replace=False)
                      for _ in range(R)]).astype(np.int32)
    got = np.asarray(_compose_inverse(jnp.asarray(perm),
                                      jnp.asarray(fresh),
                                      jnp.asarray(d_old),
                                      jnp.asarray(delta)))
    for r in range(R):
        want = _compose_oracle(perm[r], fresh[r], d_old[r], delta)
        np.testing.assert_array_equal(got[r], want)
        # and it IS a bijection: every server exactly once
        np.testing.assert_array_equal(np.sort(got[r]), np.arange(S))


def _fresh_cluster(n_servers=24, per_chassis=4, cores=40):
    return ClusterState(
        n_servers=n_servers, cores_per_server=cores,
        chassis_of_server=np.arange(n_servers) // per_chassis,
        n_chassis=n_servers // per_chassis)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_rank_order_survives_random_interleavings(seed):
    """Randomized arrival/departure/migration rounds: the maintained
    permutation must keep producing the sequential from-scratch
    oracle's decision on every arrival (fixed shapes, so the jit
    caches across examples)."""
    rng = np.random.default_rng(seed)
    policy = SchedulerPolicy(alpha=0.8)
    st_np = _fresh_cluster()
    B = 12
    placed: list = []
    with jax.experimental.enable_x64():
        dst = device_state(copy.deepcopy(st_np), jnp.float64)
        for _ in range(3):
            cores = rng.choice([1, 2, 4, 8], B).astype(np.float64)
            is_uf = rng.random(B) < 0.5
            p95 = rng.uniform(0.05, 1.0, B)
            want = []
            for i in range(B):
                s = policy.choose(st_np, int(cores[i]), bool(is_uf[i]))
                want.append(-1 if s is None else s)
                if s is not None:
                    st_np.place(s, int(cores[i]), float(p95[i]),
                                bool(is_uf[i]))
                    placed.append((s, cores[i], p95[i], is_uf[i]))
            dst, srvs = place_batch(dst, cores, is_uf, p95,
                                    np.ones(B, bool),
                                    np.full(st_np.n_chassis, np.inf),
                                    policy, st_np.cores_per_server)
            assert [int(x) for x in np.asarray(srvs)] == want
            if not placed:
                continue
            k = int(rng.integers(0, len(placed) // 2 + 1))
            if k == 0:
                continue
            pick = sorted(rng.choice(len(placed), k, replace=False)
                          .tolist())
            dep = [placed[j] for j in pick]
            placed = [p for j, p in enumerate(placed)
                      if j not in set(pick)]
            for s, c, p, u in dep:
                st_np.remove(int(s), float(c), float(p), bool(u))
            dst = remove_batch(
                dst, jnp.asarray([d[0] for d in dep], jnp.int32),
                jnp.asarray([d[1] for d in dep]),
                jnp.asarray([d[2] for d in dep]),
                jnp.asarray([bool(d[3]) for d in dep]))
        np.testing.assert_array_equal(np.asarray(dst.free_cores),
                                      st_np.free_cores)


# --- token-pool conservation under cap/depart/adapt -----------------------


@pytest.fixture(scope="module")
def serve_world():
    from repro.core import features as F
    from repro.core.predictor import train_service
    from repro.sim.telemetry import generate_population
    pop = generate_population(400, seed=0)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=12)
    return svc, hist, labels, arrivals


def _pool_invariants(pipe):
    """The conservation triple after an adaptive retarget: free >= 0,
    and free == max(base * ratio - committed, 0) per shard — checked
    per resource axis (the controller's ratio scales watts only; the
    unbudgeted +inf axes are vacuously conserved)."""
    free = np.asarray(pipe.sharded.pool)                  # (N, R)
    committed = np.asarray(pipe.sharded.shards.res_peak).sum(1)
    base = np.asarray(pipe._pool_base)                    # (N, R)
    ratio = np.asarray(pipe.adaptive_ratio, np.float64)   # (N,)
    mult = np.column_stack(
        [ratio, np.ones_like(ratio), np.ones_like(ratio)])
    assert (free >= 0).all()
    finite = np.isfinite(base)
    np.testing.assert_allclose(
        free[finite],
        np.maximum(base * mult - committed, 0)[finite], rtol=1e-5,
        atol=1e-4)
    return committed[:, 0]


def test_token_pools_conserved_through_random_sequences(serve_world):
    """Randomized cap -> arrive -> depart -> adapt interleavings on a
    4-shard pipeline with both planes live: after every cap scan the
    pools sit exactly on the retarget formula, committed rho is only
    ever moved by placements/departures (never by the controller),
    and no pool goes negative. Sequences come from the seeded
    generator (fixed shapes keep the jit cache warm across runs)."""
    from repro.sim.telemetry import arrival_batch
    svc, hist, labels, arrivals = serve_world
    for seed in range(4):
        rng = np.random.default_rng(seed)
        acfg = AdaptiveConfig(window=8, min_history=2, ratio_max=3.0)
        pipe = ShardedServePipeline.from_history(
            svc, hist, labels, n_servers=48, cores_per_server=40,
            blades_per_chassis=12,
            config=ShardedServeConfig(
                batch_size=32, n_shards=4,
                planes=PlaneBundle(
                    adaptive=acfg,
                    emergency=EmergencyConfig.from_model(1860.0),
                    cluster_budget=ResourceVector(watts=40000.0))))
        t = 1.0
        placed: list = []
        idx = np.arange(4)
        for _ in range(8):
            op = rng.choice(["cap_cool", "cap_hot", "arrive", "depart"])
            if op.startswith("cap"):
                pw = np.full(4, 500.0 if op == "cap_cool" else 6000.0)
                pipe.cap_to(0, idx, pw, t=t + (idx + 1) * 1e-7)
                t += 1.0
                pipe.flush()
                before = np.asarray(
                    pipe.sharded.shards.rho_peak).sum()
                committed = _pool_invariants(pipe)
                # a cap scan must not move committed rho at all
                np.testing.assert_allclose(committed.sum(), before)
            elif op == "arrive":
                lo = int(rng.integers(0, len(arrivals.vms) - 32))
                b = arrival_batch(arrivals, np.arange(lo, lo + 32))
                r = pipe.serve(b)           # queue-bypassing sync path
                srv = np.asarray(r.server)
                for i in np.flatnonzero(srv >= 0):
                    placed.append((int(srv[i]), float(b.cores[i]),
                                   float(r.p95_eff[i]),
                                   bool(r.workload_type[i])))
            elif op == "depart" and placed:
                k = int(rng.integers(1, min(len(placed), 8) + 1))
                pick = sorted(rng.choice(len(placed), k, replace=False)
                              .tolist())
                dep = [placed[j] for j in pick]
                placed = [p for j, p in enumerate(placed)
                          if j not in set(pick)]
                for s, c, p, u in dep:
                    pipe.depart_to(0, np.array([s]), np.array([c]),
                                   np.array([p]), np.array([u]),
                                   t=np.array([t]))
                    t += 1e-3
                t += 1.0
                pipe.flush()
        pipe.flush()
        assert (np.asarray(pipe.sharded.pool) >= 0).all()
