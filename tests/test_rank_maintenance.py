"""Scatter-free rank maintenance (`serve.placement`) vs the argsort
oracle (DESIGN.md §13).

The batched placement scan keeps the rank-rule order as a permutation
maintained by binary-search landing positions + a closed-form
histogram compose — never an S-sized scatter and never a re-sort. The
oracle is `SchedulerPolicy.choose` stepped one arrival at a time,
which recomputes the full argsort-based rank weighting from scratch on
every call: any drift in the incremental permutation (a missed rank
delta, a stale key after a departure, a broken tie) shows up as a
decision mismatch. Everything runs under x64 where the scan is
bit-equivalent to the numpy rule, so equality is exact — no tolerance
hides an off-by-one rank."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.placement import ClusterState, SchedulerPolicy
from repro.serve import device_state, place_batch, remove_batch


def _fresh(n_servers, per_chassis, cores):
    return ClusterState(
        n_servers=n_servers, cores_per_server=cores,
        chassis_of_server=np.arange(n_servers) // per_chassis,
        n_chassis=n_servers // per_chassis)


def _oracle_round(st_np, policy, cores, is_uf, p95):
    """Sequential choose+place — the from-scratch argsort oracle."""
    want = []
    for i in range(len(cores)):
        s = policy.choose(st_np, int(cores[i]), bool(is_uf[i]))
        want.append(-1 if s is None else s)
        if s is not None:
            st_np.place(s, int(cores[i]), float(p95[i]), bool(is_uf[i]))
    return want


def _device_round(dst, policy, cores, is_uf, p95, cps, n_chassis):
    dst, srvs = place_batch(dst, cores, is_uf, p95,
                            np.ones(len(cores), bool),
                            np.full(n_chassis, np.inf), policy, cps)
    return dst, [int(x) for x in np.asarray(srvs)]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_interleaved_arrivals_departures_migrations(seed):
    """Property: across rounds of place / depart / migrate (a departed
    VM's spec re-arrives next round), every decision equals the
    sequential oracle and the final aggregates match exactly."""
    rng = np.random.default_rng(seed)
    policy = SchedulerPolicy(alpha=0.8)
    st_np = _fresh(36, 12, 40)
    B = 24
    placed: list[tuple] = []
    migrants: list[tuple] = []
    with jax.experimental.enable_x64():
        dst = device_state(copy.deepcopy(st_np), jnp.float64)
        for _ in range(5):
            n_new = B - len(migrants)
            cores = np.concatenate([
                np.array([m[1] for m in migrants], np.float64),
                rng.choice([1, 2, 4, 8, 16], n_new).astype(np.float64)])
            is_uf = np.concatenate([
                np.array([m[3] for m in migrants], bool),
                rng.random(n_new) < 0.5])
            p95 = np.concatenate([
                np.array([m[2] for m in migrants], np.float64),
                rng.uniform(0.05, 1.0, n_new)])
            migrants = []
            want = _oracle_round(st_np, policy, cores, is_uf, p95)
            dst, got = _device_round(dst, policy, cores, is_uf, p95,
                                     st_np.cores_per_server,
                                     st_np.n_chassis)
            assert got == want
            placed += [(s, cores[i], p95[i], is_uf[i])
                       for i, s in enumerate(want) if s >= 0]
            if not placed:
                continue
            k = int(rng.integers(1, max(len(placed) // 3, 2)))
            pick = set(rng.choice(len(placed), size=min(k, len(placed)),
                                  replace=False).tolist())
            dep = [placed[j] for j in sorted(pick)]
            placed = [p for j, p in enumerate(placed) if j not in pick]
            # half the departures come back as migrations next round
            migrants = dep[: len(dep) // 2]
            for s, c, p, u in dep:
                st_np.remove(int(s), int(c), float(p), bool(u))
            dst = remove_batch(
                dst, jnp.asarray([d[0] for d in dep], jnp.int32),
                jnp.asarray([d[1] for d in dep]),
                jnp.asarray([d[2] for d in dep]),
                jnp.asarray([bool(d[3]) for d in dep]))
        np.testing.assert_array_equal(np.asarray(dst.free_cores),
                                      st_np.free_cores)
        np.testing.assert_allclose(np.asarray(dst.rho_peak),
                                   st_np.rho_peak, rtol=0, atol=0)


def test_rank_ties_identical_arrivals():
    """Edge: an empty cluster + identical arrivals makes every server
    key tie — placement must break ties exactly like the oracle's
    stable argsort, arrival after arrival."""
    policy = SchedulerPolicy(alpha=0.8)
    st_np = _fresh(24, 4, 40)
    B = 16
    cores = np.full(B, 2.0)
    p95 = np.full(B, 0.5)
    with jax.experimental.enable_x64():
        dst = device_state(copy.deepcopy(st_np), jnp.float64)
        for is_uf in (np.ones(B, bool),
                      np.arange(B) % 2 == 0):    # mixed-type tie round
            want = _oracle_round(st_np, policy, cores, is_uf, p95)
            dst, got = _device_round(dst, policy, cores, is_uf, p95,
                                     st_np.cores_per_server,
                                     st_np.n_chassis)
            assert got == want


def test_full_servers_fail_then_reopen():
    """Edge: filling every server drives the infeasible path (all
    FAIL codes, permutation must survive a zero-feasible batch), then
    departures reopen capacity and ranks must be consistent again."""
    policy = SchedulerPolicy(alpha=0.8)
    st_np = _fresh(4, 2, 8)
    with jax.experimental.enable_x64():
        dst = device_state(copy.deepcopy(st_np), jnp.float64)
        cores = np.full(6, 8.0)
        is_uf = np.array([True, False, True, False, True, False])
        p95 = np.full(6, 0.6)
        want = _oracle_round(st_np, policy, cores, is_uf, p95)
        dst, got = _device_round(dst, policy, cores, is_uf, p95,
                                 st_np.cores_per_server, st_np.n_chassis)
        assert got == want
        assert want[4:] == [-1, -1]         # cluster exactly full
        # free two servers, then place into the reopened capacity
        for s in (want[1], want[2]):
            st_np.remove(int(s), 8, 0.6, bool(is_uf[want.index(s)]))
        dep = np.array([want[1], want[2]], np.int32)
        dst = remove_batch(dst, jnp.asarray(dep),
                           jnp.asarray([8.0, 8.0]),
                           jnp.asarray([0.6, 0.6]),
                           jnp.asarray([is_uf[want.index(int(d))]
                                        for d in dep]))
        cores2 = np.array([4.0, 4.0, 8.0, 8.0])
        is_uf2 = np.array([True, True, False, False])
        p952 = np.array([0.3, 0.9, 0.5, 0.5])
        want2 = _oracle_round(st_np, policy, cores2, is_uf2, p952)
        dst, got2 = _device_round(dst, policy, cores2, is_uf2, p952,
                                  st_np.cores_per_server,
                                  st_np.n_chassis)
        assert got2 == want2
        np.testing.assert_array_equal(np.asarray(dst.free_cores),
                                      st_np.free_cores)
