import pytest

from repro.core.placement import SchedulerPolicy
from repro.sim.scheduler_sim import PredictionChannel, SimSpec, simulate

DAYS = 4.0      # short CI runs; the Fig 7 benchmark uses 30 days
SPEC = SimSpec(days=DAYS, seed=0)


@pytest.fixture(scope="module")
def norule():
    return simulate(SchedulerPolicy(use_power_rule=False),
                    PredictionChannel("none"), SPEC)


@pytest.fixture(scope="module")
def ours():
    return simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                    SPEC)


def test_policy_improves_chassis_balance(norule, ours):
    assert ours.chassis_score_std < norule.chassis_score_std


def test_policy_improves_server_balance(norule, ours):
    assert ours.server_score_std < norule.server_score_std


def test_failure_rate_not_degraded(norule, ours):
    assert ours.failure_rate <= norule.failure_rate + 0.01


def test_alpha_extremes_match_paper_findings():
    a0 = simulate(SchedulerPolicy(alpha=0.0), PredictionChannel("ml"),
                  SPEC)
    a1 = simulate(SchedulerPolicy(alpha=1.0), PredictionChannel("ml"),
                  SPEC)
    a08 = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                   SPEC)
    # alpha=0 ignores the chassis score -> worse chassis balance than 0.8
    assert a08.chassis_score_std < a0.chassis_score_std
    # alpha=1 ignores the server score -> worse server balance than 0.8
    assert a08.server_score_std < a1.server_score_std


def test_oracle_not_worse_than_ml():
    ml = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                  SPEC)
    oracle = simulate(SchedulerPolicy(alpha=0.8),
                      PredictionChannel("oracle"), SPEC)
    assert oracle.chassis_score_std <= ml.chassis_score_std * 1.15


def test_metrics_sane(ours):
    assert 0 <= ours.failure_rate <= 1
    assert 0 <= ours.empty_server_ratio <= 1
    assert ours.placements > 100
