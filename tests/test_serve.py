"""Online serving pipeline (`repro.serve`) — parity and behavior.

The serve subsystem is a device twin of existing host code, so almost
every test is an oracle comparison: jnp featurizer vs
`core/features.py`, batched inference vs `PredictionService.query`,
batched placement vs `SchedulerPolicy.choose` stepped one arrival at a
time, and the scheduler simulation's serve backend vs the event-driven
oracle."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import features as F
from repro.core.placement import (ClusterState, SchedulerPolicy,
                                  _score_chassis_scalar,
                                  _score_server_scalar)
from repro.core.predictor import train_service
from repro.serve import (FAIL_CAPACITY, FAIL_POWER, PlaneBundle,
                         ResourceVector, ServeConfig,
                         ServePipeline, device_state, featurize_batch,
                         headroom_w, pack_service, place_batch,
                         projected_chassis_power, remove_batch,
                         rho_cap_from_budget, score_chassis_batch,
                         score_server_batch, served_query,
                         table_from_history)
from repro.sim.telemetry import (arrival_batch, generate_population,
                                 stream_arrivals)


@pytest.fixture(scope="module")
def world():
    pop = generate_population(700, seed=0)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=12)
    cap = max(v.subscription for v in pop.vms) + 8
    table = table_from_history(hist, labels, cap)
    return dict(pop=pop, hist=hist, arrivals=arrivals, labels=labels,
                aggs=aggs, svc=svc, table=table)


# --- featurizer parity ----------------------------------------------------

def test_featurizer_matches_numpy_oracle(world):
    want = F.build_features(world["arrivals"], world["aggs"])
    got = np.asarray(featurize_batch(world["table"],
                                     arrival_batch(world["arrivals"])))
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_featurizer_incremental_equals_bulk(world):
    hist, labels = world["hist"], world["labels"]
    n = len(hist.vms) // 2
    cap = world["table"].capacity
    t2 = table_from_history(F.Population(vms=hist.vms[:n]), labels[:n],
                            cap)
    pipe_like = table_from_history(F.Population(vms=hist.vms[n:]),
                                   labels[n:], cap)
    merged = type(t2)(*(a + b for a, b in zip(t2, pipe_like)))
    for a, b in zip(merged, world["table"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3)


def test_p95_bucket_boundaries_match_host_in_f32():
    """Exact bucket edges (integer-percent telemetry) must bucket like
    the f64 host despite f32 inputs — the host's 1e-9 epsilon
    underflows in f32, the ceil formulation does not."""
    from repro.serve.featurizer import p95_bucket_jnp
    vals = np.array([0.0, 1.0, 24.999, 25.0, 25.001, 50.0, 74.5, 75.0,
                     99.0, 100.0])
    want = F.p95_bucket(vals.astype(np.float64))
    got = np.asarray(p95_bucket_jnp(jnp.asarray(vals, jnp.float32)))
    np.testing.assert_array_equal(got, want)


def test_featurizer_default_row_for_unseen_subscription(world):
    b = arrival_batch(world["arrivals"], [0])
    b.subscription[:] = world["table"].capacity - 1    # never observed
    got = np.asarray(featurize_batch(world["table"], b))[0]
    assert got[0] == pytest.approx(F._DEFAULT_AGG["pct_uf"])
    assert got[2] == 0.0                               # sub_total_vms
    np.testing.assert_allclose(got[3:7], F._DEFAULT_AGG["bucket_mix"])


def test_featurizer_out_of_capacity_ids_fall_back_and_drop(world):
    from repro.serve import update_table
    table = world["table"]
    cap = table.capacity
    # featurize: an id past capacity must get the default row, not a
    # clamped gather of the last populated row
    b = arrival_batch(world["arrivals"], [0])
    b.subscription[:] = cap + 5
    got = np.asarray(featurize_batch(table, b))[0]
    assert got[0] == pytest.approx(F._DEFAULT_AGG["pct_uf"])
    assert got[2] == 0.0
    # update: an id past capacity is dropped, not wrapped/clamped
    t2 = update_table(table, jnp.asarray([cap + 5, -3], jnp.int32),
                      jnp.ones(2), jnp.ones(2) * 200.0,
                      jnp.ones(2) * 50.0, jnp.ones(2) * 30.0)
    for a, b_ in zip(t2, table):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_featurizer_padding_rows_dropped(world):
    b = arrival_batch(world["arrivals"], np.arange(5))
    unpadded = np.asarray(featurize_batch(world["table"], b))
    padded = np.asarray(featurize_batch(world["table"], b, pad_to=16))
    np.testing.assert_array_equal(padded[:5], unpadded)
    assert padded.shape[0] == 16


# --- batched inference ----------------------------------------------------

def test_served_query_matches_prediction_service(world):
    x = F.build_features(world["arrivals"], world["aggs"])
    want = world["svc"].query(x)
    packed, meta = pack_service(world["svc"])
    got = served_query(packed, meta, jnp.asarray(x), kernel="ref")
    np.testing.assert_allclose(np.asarray(got["workload_conf"]),
                               want["workload_conf"], atol=1e-5)
    np.testing.assert_allclose(np.asarray(got["p95_conf"]),
                               want["p95_conf"], atol=1e-5)
    for k in ("workload_type_used", "p95_bucket_used"):
        agree = (np.asarray(got[k]) == want[k]).mean()
        assert agree >= 0.995, f"{k} agreement {agree}"


def test_served_query_pallas_interpret_matches_ref(world):
    x = F.build_features(world["arrivals"], world["aggs"])[:8]
    packed, meta = pack_service(world["svc"])
    ref = served_query(packed, meta, jnp.asarray(x), kernel="ref")
    pal = served_query(packed, meta, jnp.asarray(x),
                       kernel="pallas_interpret")
    np.testing.assert_allclose(np.asarray(pal["workload_conf"]),
                               np.asarray(ref["workload_conf"]),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(pal["p95_bucket_used"]),
                                  np.asarray(ref["p95_bucket_used"]))


def test_served_query_conservative_fallback(world):
    x = F.build_features(world["arrivals"], world["aggs"])
    packed, meta = pack_service(world["svc"])
    got = served_query(packed, meta, jnp.asarray(x), kernel="ref")
    cons = np.asarray(got["conservative"])
    wt = np.asarray(got["workload_type_used"])
    pb = np.asarray(got["p95_bucket_used"])
    low_wt = np.asarray(got["workload_conf"]) < meta.confidence_gate
    low_pb = np.asarray(got["p95_conf"]) < meta.confidence_gate
    np.testing.assert_array_equal(cons, low_wt | low_pb)
    assert (wt[low_wt] == 1).all()          # UF fallback
    assert (pb[low_pb] == 3).all()          # bucket-4 fallback


# --- batched placement vs the scalar/sequential oracles -------------------

def _loaded_state(seed, n_servers=24, per_chassis=4, cores=40, n=60):
    rng = np.random.default_rng(seed)
    st = ClusterState(n_servers=n_servers, cores_per_server=cores,
                      chassis_of_server=np.arange(n_servers) // per_chassis,
                      n_chassis=n_servers // per_chassis)
    for _ in range(n):
        srv = int(rng.integers(0, n_servers))
        c = int(rng.integers(1, 8))
        if st.free_cores[srv] < c:
            continue
        st.place(srv, c, float(rng.uniform(0, 1)),
                 bool(rng.random() < 0.5))
    return st


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_score_batches_match_scalar_oracles(seed):
    st = _loaded_state(seed)
    dst = device_state(st)
    kappa = np.asarray(score_chassis_batch(dst))
    for c in range(st.n_chassis):
        assert kappa[c] == pytest.approx(_score_chassis_scalar(st, c),
                                         abs=1e-6)
    for uf in (True, False):
        eta = np.asarray(score_server_batch(dst, uf, 40))
        for s in range(st.n_servers):
            assert eta[s] == pytest.approx(
                _score_server_scalar(st, s, uf), abs=1e-6)
    # batched over arrival types: (B, S)
    eta2 = np.asarray(score_server_batch(dst, np.array([True, False]), 40))
    np.testing.assert_allclose(eta2[0],
                               np.asarray(score_server_batch(dst, True,
                                                             40)))


@pytest.mark.parametrize("policy", [
    SchedulerPolicy(alpha=0.8),
    SchedulerPolicy(alpha=0.0),
    SchedulerPolicy(alpha=0.8, packing_weight=0.0),   # Algorithm-1 mode
    SchedulerPolicy(power_weight=0.0),                # best-fit mode
    SchedulerPolicy(use_power_rule=False),
])
def test_place_batch_matches_sequential_choose_x64(policy):
    """The key equivalence: one x64 scan == `choose`+`place` stepped
    per arrival, on a randomized part-loaded cluster (both fast and
    subset-rank paths exercised via large/small arrivals)."""
    st = _loaded_state(3, n_servers=36, per_chassis=12, n=200)
    rng = np.random.default_rng(7)
    B = 48
    cores = rng.choice([1, 2, 4, 8, 16, 32], B).astype(np.float64)
    is_uf = rng.random(B) < 0.4
    p95 = rng.uniform(0.05, 1.0, B)
    st_np = copy.deepcopy(st)
    want = []
    for i in range(B):
        s = policy.choose(st_np, int(cores[i]), bool(is_uf[i]))
        want.append(-1 if s is None else s)
        if s is not None:
            st_np.place(s, int(cores[i]), float(p95[i]), bool(is_uf[i]))
    with jax.experimental.enable_x64():
        dst, srvs = place_batch(
            device_state(st, jnp.float64), cores, is_uf, p95,
            np.ones(B, bool), np.full(st.n_chassis, np.inf), policy,
            st.cores_per_server)
        got = [int(x) for x in np.asarray(srvs)]
    assert got == want
    np.testing.assert_allclose(np.asarray(dst.free_cores),
                               st_np.free_cores)
    np.testing.assert_allclose(np.asarray(dst.rho_peak), st_np.rho_peak)


def test_place_batch_f32_close_to_oracle():
    """The f32 serving path may flip rare near-tie ranks; the bound we
    document in DESIGN.md §9 is checked here."""
    st = _loaded_state(4, n_servers=36, per_chassis=12, n=200)
    rng = np.random.default_rng(8)
    B = 64
    cores = rng.choice([1, 2, 4, 8], B).astype(np.float32)
    is_uf = rng.random(B) < 0.4
    p95 = rng.uniform(0.05, 1.0, B).astype(np.float32)
    policy = SchedulerPolicy(alpha=0.8)
    st_np = copy.deepcopy(st)
    want = []
    for i in range(B):
        s = policy.choose(st_np, int(cores[i]), bool(is_uf[i]))
        want.append(-1 if s is None else s)
        if s is not None:
            st_np.place(s, int(cores[i]), float(p95[i]), bool(is_uf[i]))
    _, srvs = place_batch(device_state(st), cores, is_uf, p95,
                          np.ones(B, bool),
                          np.full(st.n_chassis, np.inf, np.float32),
                          policy, st.cores_per_server)
    agree = np.mean(np.asarray(srvs) == np.asarray(want))
    assert agree >= 0.9


def test_place_batch_padding_and_capacity_failure():
    st = ClusterState(n_servers=2, cores_per_server=4,
                      chassis_of_server=np.array([0, 1]), n_chassis=2)
    dst = device_state(st)
    cores = np.array([4, 4, 1, 7], np.float32)
    valid = np.array([True, True, True, False])
    dst, srvs = place_batch(dst, cores, np.ones(4, bool),
                            np.full(4, 0.5, np.float32), valid,
                            np.full(2, np.inf, np.float32),
                            SchedulerPolicy(), 4)
    srvs = np.asarray(srvs)
    assert set(srvs[:2]) == {0, 1}
    assert srvs[2] == FAIL_CAPACITY            # cluster is full
    assert np.asarray(dst.free_cores).sum() == 0


def test_admission_rejects_over_budget_and_leaves_state():
    st = ClusterState(n_servers=4, cores_per_server=40,
                      chassis_of_server=np.zeros(4, np.int64),
                      n_chassis=1)
    dst = device_state(st)
    # cap admits ~one 20-core @ p95=1.0 placement
    rho_cap = np.array([25.0], np.float32)
    cores = np.full(3, 20.0, np.float32)
    dst2, srvs = place_batch(dst, cores, np.ones(3, bool),
                             np.ones(3, np.float32), np.ones(3, bool),
                             rho_cap, SchedulerPolicy(), 40)
    srvs = np.asarray(srvs)
    assert (srvs >= 0).sum() == 1
    assert (srvs == FAIL_POWER).sum() == 2
    assert np.asarray(dst2.rho_peak)[0] == pytest.approx(20.0)
    # rejected placements must not have mutated free cores
    assert np.asarray(dst2.free_cores).sum() == pytest.approx(160 - 20)


def test_rho_cap_and_headroom_roundtrip():
    cap = rho_cap_from_budget(2450.0, 12, 3)
    assert cap.shape == (3,)
    st = ClusterState(n_servers=36, cores_per_server=40,
                      chassis_of_server=np.arange(36) // 12, n_chassis=3)
    st.place(0, 10, 0.8, True)
    dst = device_state(st)
    proj = projected_chassis_power(dst, 12)
    head = headroom_w(dst, 2450.0, 12)
    np.testing.assert_allclose(proj + head, 2450.0, rtol=1e-5)
    # the admission inequality and the watt headroom agree in sign
    assert (np.asarray(dst.rho_peak) <= cap).all() == (head >= 0).all()


def test_headroom_none_budget_is_infinite():
    st = ClusterState(n_servers=12, cores_per_server=40,
                      chassis_of_server=np.zeros(12, np.int64),
                      n_chassis=1)
    assert np.isinf(headroom_w(device_state(st), None, 12)).all()


def test_place_remove_roundtrip_bit_exact_x64():
    st = _loaded_state(6)
    cores = np.array([4.0, 8.0])
    uf = np.array([True, False])
    p95 = np.array([0.7318291, 0.2912347])
    with jax.experimental.enable_x64():
        dst0 = device_state(st, jnp.float64)
        dst, srvs = place_batch(dst0, cores, uf, p95, np.ones(2, bool),
                                np.full(st.n_chassis, np.inf),
                                SchedulerPolicy(), 40)
        dst = remove_batch(dst, srvs, cores, p95, uf)
        for a, b in zip(dst, dst0):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_remove_batch_roundtrip():
    st = _loaded_state(5)
    dst0 = device_state(st)
    cores = np.array([4, 2], np.float32)
    uf = np.array([True, False])
    p95 = np.array([0.7, 0.3], np.float32)
    dst, srvs = place_batch(dst0, cores, uf, p95, np.ones(2, bool),
                            np.full(st.n_chassis, np.inf, np.float32),
                            SchedulerPolicy(), 40)
    dst = remove_batch(dst, srvs, cores, p95, uf)
    for a, b in zip(dst, dst0):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)
    # negative server codes are ignored
    dst = remove_batch(dst, np.array([FAIL_CAPACITY]),
                       np.array([4.0], np.float32),
                       np.array([0.5], np.float32), np.array([True]))
    np.testing.assert_allclose(np.asarray(dst.free_cores),
                               np.asarray(dst0.free_cores), atol=1e-5)


# --- pipeline -------------------------------------------------------------

def test_pipeline_end_to_end_counts(world):
    pipe = ServePipeline.from_history(
        world["svc"], world["hist"], world["labels"], n_servers=36,
        cores_per_server=40, blades_per_chassis=12,
        config=ServeConfig(batch_size=32))
    results = []
    for _, b in stream_arrivals(world["arrivals"], 20):
        results += pipe.submit(b)
    tail = pipe.flush()
    if tail is not None:
        results.append(tail)
    total = sum(len(r.server) for r in results)
    assert total == len(world["arrivals"].vms)
    assert pipe.served == total
    admitted = sum(r.n_admitted for r in results)
    assert admitted > 0
    for r in results:
        ok = r.server >= 0
        assert (r.server[ok] < 36).all()
        assert r.n_admitted + r.n_capacity_rejected \
            + r.n_power_rejected == len(r.server)


def test_pipeline_hot_swap_drops_no_arrivals(world):
    pipe = ServePipeline.from_history(
        world["svc"], world["hist"], world["labels"], n_servers=36,
        cores_per_server=40, blades_per_chassis=12,
        config=ServeConfig(batch_size=16))
    first = pipe.submit(arrival_batch(world["arrivals"], np.arange(24)))
    svc2 = train_service(
        F.build_features(world["hist"], world["aggs"]),
        world["labels"].astype(np.int64),
        F.p95_bucket([v.p95_util for v in world["hist"].vms]),
        n_trees=12, seed=9)
    pipe.hot_swap(svc2)                  # 8 arrivals still queued
    rest = pipe.flush()
    served = sum(len(r.server) for r in first) + len(rest.server)
    assert served == 24
    assert pipe.swaps == 1
    # the standby model now serves
    out = pipe.serve(arrival_batch(world["arrivals"], np.arange(24, 40)))
    assert len(out.server) == 16


def test_pipeline_power_budget_rejects(world):
    tight = ServePipeline.from_history(
        world["svc"], world["hist"], world["labels"], n_servers=24,
        cores_per_server=40, blades_per_chassis=12,
        config=ServeConfig(
            batch_size=64,
            planes=PlaneBundle(chassis_budget=ResourceVector(
                watts=12 * 112.0 + 40.0))))  # ~no dynamic headroom
    res = tight.serve(arrival_batch(world["arrivals"], np.arange(64)))
    assert res.n_power_rejected > 0
    assert (tight.chassis_headroom_w(12 * 112.0 + 40.0) >= -1e-3).all()


def test_pipeline_observe_updates_aggregates(world):
    pipe = ServePipeline.from_history(
        world["svc"], world["hist"], world["labels"], n_servers=12,
        cores_per_server=40, blades_per_chassis=12)
    before = float(np.asarray(pipe.table.count).sum())
    pipe.observe(F.Population(vms=world["arrivals"].vms[:10]),
                 np.ones(10))
    after = float(np.asarray(pipe.table.count).sum())
    assert after == pytest.approx(before + 10)


# --- streaming arrivals ---------------------------------------------------

def test_stream_arrivals_covers_population(world):
    pop = world["arrivals"]
    seen = 0
    last_t = 0.0
    for t, b in stream_arrivals(pop, 33, arrival_rate_per_s=10.0):
        assert t > last_t
        last_t = t
        assert len(b) <= 33
        seen += len(b)
    assert seen == len(pop.vms)


# --- scheduler simulation backend ----------------------------------------

def test_scheduler_serve_backend_reproduces_event_oracle():
    """Acceptance: for the same arrival sequence and fixed predictions,
    backend='serve' reproduces the event-driven scheduler's placements
    decision-for-decision (x64 scan == f64 host rule)."""
    from repro.sim.scheduler_sim import (PredictionChannel,
                                         ServeBackendSpec, SimSpec,
                                         simulate)
    tr_e, tr_s = [], []
    e = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                 SimSpec(days=1.0, seed=0), trace=tr_e)
    s = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                 SimSpec(days=1.0, seed=0,
                         serve=ServeBackendSpec(backend="serve")),
                 trace=tr_s)
    assert tr_e == tr_s
    assert e.failure_rate == s.failure_rate
    assert e.chassis_score_std == s.chassis_score_std
    assert e.server_score_std == s.server_score_std
    assert e.empty_server_ratio == s.empty_server_ratio


def test_scheduler_serve_backend_admission_budget():
    from repro.sim.scheduler_sim import (PredictionChannel,
                                         ServeBackendSpec, SimSpec,
                                         simulate)
    free = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                    SimSpec(days=0.5, seed=0,
                            serve=ServeBackendSpec(backend="serve")))
    tight = simulate(
        SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
        SimSpec(days=0.5, seed=0, serve=ServeBackendSpec(
            backend="serve",
            admission_budget=ResourceVector(watts=12 * 112.0 + 60.0))))
    # ~60 W of dynamic headroom per chassis power-rejects a large
    # share of placements that an unbudgeted run admits freely
    assert free.failure_rate < 0.01
    assert tight.failure_rate > 0.2
