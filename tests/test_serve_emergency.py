"""Online power-emergency plane (`repro.serve.emergency` /
`repro.serve.mitigation`) — oracle parity and invariants.

The contract under test (docs/emergency.md, DESIGN.md §12):

  * the batched apportionment equals an independent greedy numpy
    oracle built from `ChassisManager` / `PerVMController`, and the
    vmap and shard_map executions of the sharded emergency scan agree
    with the numpy kernel chassis-for-chassis;
  * `simulate(backend='serve-sharded')` with emergencies enabled stays
    decision-identical to the event-driven oracle at 1 shard and
    host-count-invariant at any shard count;
  * migration plans are deterministic and invariant to how their
    paired depart/arrive events are dealt across ingest hosts, and a
    full cap -> migrate -> uncap cycle conserves the power-token
    pools;
  * criticality-aware apportionment strictly beats the
    criticality-blind baseline on critical throttled-seconds over the
    same trace.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.capping import ChassisManager, PerVMController
from repro.core.fleet_dynamics import FREQ_TABLE
from repro.core.placement import ClusterState, SchedulerPolicy
from repro.core.power_model import N_PSTATES, ServerPowerModel, dyn_scale
from repro.serve import (CRIT_UF, EmergencyConfig, PlaneBundle,
                         ResourceVector, apply_caps_sharded,
                         chassis_rho_levels, device_state,
                         emergency_step, init_emergency,
                         init_emergency_sharded, masked_step,
                         mitigation_due, plan_migrations,
                         rho_pool_from_budget, sampled_power,
                         scatter_samples, shard_mesh, shard_state,
                         throttled_by_level)
from repro.serve.mitigation import LiveVMs
from repro.sim.scheduler_sim import (PredictionChannel, ServeBackendSpec,
                                     SimSpec, simulate)

#: The paper's 2x-oversubscription operating point: a 12-blade chassis
#: provisioned at 12 x 310 W peak, budgeted at half.
BUDGET_2X = 12 * 310.0 / 2.0

#: Stress budget for short tier-1 runs: barely above the static floor,
#: so alarms trip at any occupancy without simulating to midday.
BUDGET_TIGHT = 1480.0


def _cfg(budget=BUDGET_TIGHT, **kw) -> EmergencyConfig:
    return EmergencyConfig.from_model(budget, **kw)


def _loaded_state(seed, n_servers=48, per_chassis=12, cores=40, n=260):
    rng = np.random.default_rng(seed)
    st = ClusterState(n_servers=n_servers, cores_per_server=cores,
                      chassis_of_server=np.arange(n_servers) // per_chassis,
                      n_chassis=n_servers // per_chassis)
    for _ in range(n):
        srv = int(rng.integers(0, n_servers))
        c = int(rng.integers(1, 8))
        if st.free_cores[srv] >= c:
            st.place(srv, c, float(rng.uniform(0.2, 1)),
                     bool(rng.random() < 0.5))
    return st


# --- apportionment vs the greedy capping oracle ---------------------------

def _greedy_oracle(cut_w, dyn_w, floors, blind=False):
    """Independent per-chassis apportionment: explicit greedy loop over
    levels with a linear p-state search — deliberately NOT the
    branchless formulation under test."""
    fracs = 1.0 - dyn_scale(FREQ_TABLE)
    L = len(dyn_w)
    rem = max(float(cut_w), 0.0)
    total = sum(dyn_w)
    pstates, takes = [], []
    for lv in range(L):
        red_max = dyn_w[lv] * fracs[floors[lv]]
        if blind:
            want = min(rem if total <= 0 else
                       max(cut_w, 0.0) * dyn_w[lv] / total, red_max)
        else:
            want = min(rem, red_max)
        p = 0
        if dyn_w[lv] > 0 and want > 0:
            ratio = want / dyn_w[lv]
            while p < N_PSTATES and fracs[p] < ratio:
                p += 1
        takes.append(want)
        pstates.append(min(p, floors[lv]))
        if not blind:
            rem -= want
    leftover = max(max(float(cut_w), 0.0) - sum(takes), 0.0)
    return pstates, takes, leftover


@pytest.mark.parametrize("blind", [False, True])
def test_apportion_matches_greedy_oracle(blind):
    rng = np.random.default_rng(0)
    ctrl = PerVMController(ServerPowerModel(), 230.0)
    floors = (N_PSTATES - 1, 5)
    for _ in range(200):
        dyn = rng.uniform(0, 400, 2)
        if rng.random() < 0.3:
            dyn[rng.integers(0, 2)] = 0.0       # zero-util level
        cut = float(rng.uniform(-20, 500))
        ps, take, left = ctrl.apportion(cut, dyn, np.asarray(floors),
                                        blind=blind)
        ops, otake, oleft = _greedy_oracle(cut, dyn, floors, blind)
        np.testing.assert_array_equal(ps, ops)
        np.testing.assert_allclose(take, otake, atol=1e-9)
        assert left == pytest.approx(oleft, abs=1e-9)


def test_emergency_alarm_matches_chassis_manager():
    cfg = _cfg(BUDGET_2X)
    mgr = cfg.manager()
    assert isinstance(mgr, ChassisManager)
    rho = np.array([[10.0, 10.0], [150.0, 150.0], [40.0, 260.0]])
    st = init_emergency(3, xp=np, dtype=np.float64)
    st, out = emergency_step(cfg, st, rho, 0.9, 1.0, np)
    np.testing.assert_array_equal(out.alarm,
                                  mgr.poll(np.asarray(out.power_w)))
    # alarmed chassis with an achievable cut land at/below the budget
    ok = out.alarm & (out.leftover_w <= 1e-6)
    assert (out.power_after_w[ok] <= cfg.chassis_budget_w + 1e-6).all()


def test_emergency_hysteresis_lift_after_clear():
    """A cleared alarm holds the cap for `lift_after_s`, then restores
    nominal frequency (the paper's 30 s lift delay)."""
    cfg = _cfg(BUDGET_2X, lift_after_s=30.0)
    rho = np.array([[200.0, 200.0]])
    st = init_emergency(1, xp=np, dtype=np.float64)
    st, out = emergency_step(cfg, st, rho, 0.95, 0.0, np)   # alarm
    assert out.alarm[0] and (st.pstate > 0).any()
    st, out = emergency_step(cfg, st, rho, 0.10, 10.0, np)  # clear, hold
    assert not out.alarm[0] and (st.pstate > 0).any()
    assert st.clear_s[0] == pytest.approx(10.0)
    st, out = emergency_step(cfg, st, rho, 0.10, 45.0, np)  # lift
    assert not (st.pstate > 0).any() and not st.rapl[0]
    assert np.isinf(st.clear_s[0])


def test_throttled_seconds_accrue_per_level():
    cfg = _cfg(BUDGET_2X)
    rho = np.array([[300.0, 60.0]])       # NUF floor absorbs the cut
    st = init_emergency(1, xp=np, dtype=np.float64)
    st, _ = emergency_step(cfg, st, rho, 0.60, 0.0, np)
    assert st.pstate[0, 0] > 0 and st.pstate[0, 1] == 0
    st, _ = emergency_step(cfg, st, rho, 0.60, 7.0, np)
    assert throttled_by_level(st)[0] == pytest.approx(7.0)
    assert throttled_by_level(st)[CRIT_UF] == 0.0


# --- vmap == shard_map == numpy oracle ------------------------------------

def _dense_samples(cfg, n_chassis, rho_lv, util, t0):
    idx = np.arange(n_chassis)
    stamps = t0 + (idx + 1) * 1e-4
    power = np.asarray(sampled_power(
        cfg, rho_lv, util, np.zeros((n_chassis, 2), np.int32),
        np.zeros(n_chassis, bool), np))
    return idx, power, stamps


@pytest.mark.parametrize("use_mesh", [False, True])
def test_sharded_scan_matches_numpy_oracle(use_mesh):
    """apply_caps_sharded (vmap, and shard_map on a 4-device runtime)
    must reproduce the numpy kernel chassis-for-chassis, in x64
    bit-exactly."""
    mesh = shard_mesh(4) if use_mesh else None
    if use_mesh and mesh is None:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=4")
    cfg = _cfg()
    st = _loaded_state(3)
    with jax.experimental.enable_x64():
        dst = device_state(st, jnp.float64)
        sharded = shard_state(dst, 4)
        emer = init_emergency_sharded(4, 4, dtype=jnp.float64)
        rho_lv = np.asarray(chassis_rho_levels(
            np.asarray(dst.gamma_nuf), np.asarray(dst.gamma_uf),
            np.asarray(dst.chassis_servers), np))
        ref = init_emergency(4, xp=np, dtype=np.float64)
        for t0, u in ((0.0, 0.9), (20.0, 0.4), (60.0, 0.95)):
            idx, power, stamps = _dense_samples(cfg, 4, rho_lv, u, t0)
            emer, out = apply_caps_sharded(cfg, sharded, emer, idx,
                                           power, stamps, mesh=mesh)
            pw, mask, ts = scatter_samples(4, idx, power, stamps, np,
                                           np.float64)
            ref, rout = masked_step(cfg, ref, rho_lv, pw, mask, ts, np)
            for a, b in zip(ref, emer):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b).reshape(a.shape))
            np.testing.assert_array_equal(
                np.asarray(rout.alarm),
                np.asarray(out.alarm).reshape(-1))


def test_sharded_rho_levels_match_global():
    st = _loaded_state(5)
    dst = device_state(st)
    sharded = shard_state(dst, 4)
    want = np.asarray(chassis_rho_levels(
        np.asarray(dst.gamma_nuf), np.asarray(dst.gamma_uf),
        np.asarray(dst.chassis_servers), np))
    got = np.stack([
        np.asarray(chassis_rho_levels(
            np.asarray(sharded.shards.gamma_nuf)[s],
            np.asarray(sharded.shards.gamma_uf)[s],
            np.asarray(sharded.shards.chassis_servers)[s], np))
        for s in range(4)]).reshape(4, 2)
    np.testing.assert_allclose(got, want, rtol=1e-6)


# --- capping edge cases (surfaced by the batched oracle) ------------------

def test_zero_util_level_takes_no_cut():
    """A level with zero dynamic draw must neither NaN nor be assigned
    a share (the zero-util division guard)."""
    ctrl = PerVMController(ServerPowerModel(), 230.0)
    ps, take, left = ctrl.apportion(50.0, np.array([0.0, 100.0]))
    assert np.isfinite(take).all() and take[0] == 0.0 and ps[0] == 0
    assert take[1] == pytest.approx(50.0) and left == 0.0


def test_all_critical_chassis_caps_before_rapl():
    """An all-critical chassis must cap its critical VMs down to their
    own floor before the leftover falls through to the RAPL backstop
    — not skip straight to the all-core throttle."""
    cfg = _cfg(BUDGET_2X)
    fracs = 1.0 - dyn_scale(FREQ_TABLE)
    dyn_crit = 300.0
    max_crit_cut = dyn_crit * fracs[cfg.floors[CRIT_UF]]
    # absorbable within the critical floor: capped, no RAPL
    ps, take, left = PerVMController(ServerPowerModel(), 230.0) \
        .apportion(0.8 * max_crit_cut, np.array([0.0, dyn_crit]),
                   np.asarray(cfg.floors))
    assert 0 < ps[1] <= cfg.floors[CRIT_UF] and left == 0.0
    # beyond the floor: critical pinned AT its floor, leftover > 0
    ps, take, left = PerVMController(ServerPowerModel(), 230.0) \
        .apportion(2.0 * max_crit_cut, np.array([0.0, dyn_crit]),
                   np.asarray(cfg.floors))
    assert ps[1] == cfg.floors[CRIT_UF] and left > 0
    # and the emergency step turns that leftover into the RAPL backstop
    st = init_emergency(1, xp=np, dtype=np.float64)
    rho = np.array([[0.0, 2.0 * max_crit_cut
                     / (cfg.p_dyn_per_core * 0.9)]])
    st, out = emergency_step(
        _cfg(BUDGET_2X, alert_fraction=0.5,
             target_margin_w=BUDGET_2X - cfg.static_w - 1.0),
        st, rho, 0.9, 0.0, np)
    assert st.rapl[0] and out.leftover_w[0] > 0


# --- sim backend identities -----------------------------------------------

SIM_KW = dict(days=0.1, seed=0, deployments_per_hour=16.0,
              prefill_core_ratio=0.6)


def _spec(cfg, backend="event", shards=1, hosts=1, **kw):
    """SimSpec on the shared short-sim settings with the emergency
    plane attached."""
    return SimSpec(serve=ServeBackendSpec(backend=backend, shards=shards,
                                          ingest_hosts=hosts),
                   emergency=cfg, **{**SIM_KW, **kw})


def test_one_shard_sim_identity_with_emergencies():
    """backend='serve-sharded' at 1 shard == the event oracle,
    trace-for-trace and emergency-metric-for-metric, with the plane
    alarming and migrating (every serve scan additionally asserts the
    jnp kernel equal to the numpy oracle in-sim)."""
    cfg = _cfg(dwell_s=120.0)
    tr_e, tr_s = [], []
    me = simulate(SchedulerPolicy(use_power_rule=False),
                  PredictionChannel("ml"), _spec(cfg), trace=tr_e)
    ms = simulate(SchedulerPolicy(use_power_rule=False),
                  PredictionChannel("ml"),
                  _spec(cfg, backend="serve-sharded"), trace=tr_s)
    assert me.alarms > 0
    assert tr_e == tr_s
    assert me.alarms == ms.alarms
    assert me.migrations == ms.migrations
    assert me.uf_throttled_s == ms.uf_throttled_s
    assert me.nuf_throttled_s == ms.nuf_throttled_s
    assert me.failure_rate == ms.failure_rate


@pytest.mark.parametrize("n_hosts", [2, 4])
def test_host_count_invariance_with_emergencies(n_hosts):
    """The full plane — arrivals, departures, emergencies, migrations
    — is invariant to the ingest host count at a fixed shard count."""
    cfg = _cfg(dwell_s=120.0)
    traces = []
    metrics = []
    for hosts in (1, n_hosts):
        tr = []
        metrics.append(simulate(
            SchedulerPolicy(use_power_rule=False),
            PredictionChannel("ml"),
            _spec(cfg, backend="serve-sharded", shards=2, hosts=hosts),
            trace=tr))
        traces.append(tr)
    assert traces[0] == traces[1]
    assert metrics[0].alarms == metrics[1].alarms
    assert metrics[0].migrations == metrics[1].migrations
    assert metrics[0].uf_throttled_s == metrics[1].uf_throttled_s


@pytest.mark.slow
def test_aware_beats_blind_at_2x_oversubscription():
    """The acceptance axis: at 2x oversubscription over the same
    trace, criticality-aware apportionment reports strictly lower
    critical throttled-seconds than the criticality-blind baseline
    (and both runs assert the budget invariant in-sim)."""
    kw = dict(days=0.55, seed=0, deployments_per_hour=16.0,
              prefill_core_ratio=0.75)
    aware = simulate(SchedulerPolicy(alpha=0.8),
                     PredictionChannel("ml"),
                     SimSpec(emergency=_cfg(BUDGET_2X, dwell_s=3600.0),
                             **kw))
    blind = simulate(SchedulerPolicy(alpha=0.8),
                     PredictionChannel("ml"),
                     SimSpec(emergency=_cfg(BUDGET_2X, dwell_s=3600.0,
                                            criticality_blind=True),
                             **kw))
    assert aware.alarms > 0
    assert 0 <= aware.uf_throttled_s < blind.uf_throttled_s


def test_aware_beats_blind_tight_budget():
    """Fast tier-1 twin of the 2x acceptance check on the stress
    budget: same trace, strictly lower critical throttled-seconds."""
    cfg_kw = dict(dwell_s=3600.0)
    aware = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                     _spec(_cfg(**cfg_kw)))
    blind = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                     _spec(_cfg(criticality_blind=True, **cfg_kw)))
    assert aware.alarms > 0
    assert aware.uf_throttled_s < blind.uf_throttled_s
    assert aware.nuf_throttled_s > 0


# --- migration planning ---------------------------------------------------

def _mig_world():
    """A cluster with one overloaded chassis full of critical VMs and
    plenty of headroom elsewhere."""
    st = _loaded_state(0, n_servers=48, per_chassis=12, n=0)
    rng = np.random.default_rng(7)
    rows = []
    for v in range(24):
        srv = int(rng.integers(0, 12))          # chassis 0
        if st.free_cores[srv] < 8:
            continue
        p95 = float(rng.uniform(0.6, 0.95))
        st.place(srv, 8, p95, True)
        rows.append((srv, 8.0, p95, True))
    for v in range(10):                          # background elsewhere
        srv = int(rng.integers(12, 48))
        p95 = float(rng.uniform(0.2, 0.5))
        st.place(srv, 4, p95, False)
        rows.append((srv, 4.0, p95, False))
    live = LiveVMs(np.array([r[0] for r in rows], np.int32),
                   np.array([r[1] for r in rows]),
                   np.array([r[2] for r in rows]),
                   np.array([r[3] for r in rows], bool))
    return st, live


def test_plan_migrations_moves_cheapest_critical_to_headroom():
    cfg = _cfg()
    st, live = _mig_world()
    rho_lv = np.zeros((4, 2))
    np.add.at(rho_lv, (np.asarray(st.chassis_of_server)[live.server],
                       live.is_uf.astype(int)),
              live.p95_eff * live.cores)
    due = np.array([True, False, False, False])
    plan = plan_migrations(cfg, live, st.chassis_of_server,
                           st.free_cores, rho_lv, 0.9, due,
                           max_moves_per_chassis=4)
    assert len(plan) > 0
    assert (np.asarray(st.chassis_of_server)[plan.src_server] == 0).all()
    assert (np.asarray(st.chassis_of_server)[plan.dst_server] != 0).all()
    assert plan.is_uf.all()
    # cheapest-first: the planned rho sequence is non-decreasing
    w = plan.p95_eff * plan.cores
    assert (np.diff(w) >= -1e-12).all()
    # determinism
    plan2 = plan_migrations(cfg, live, st.chassis_of_server,
                            st.free_cores, rho_lv, 0.9, due,
                            max_moves_per_chassis=4)
    np.testing.assert_array_equal(plan.dst_server, plan2.dst_server)


def test_migration_events_invariant_to_host_dealing(serve_world):
    """Pushing the plan's paired depart/arrive events through the
    ingest mux must yield the same final sharded state for any host
    dealing (PR 4's invariance carrying over to kind 3's siblings)."""
    from repro.serve import ShardedServeConfig, ShardedServePipeline
    svc, hist, labels, _ = serve_world
    st, live = _mig_world()
    cfg = _cfg()
    rho_lv = np.zeros((4, 2))
    np.add.at(rho_lv, (np.asarray(st.chassis_of_server)[live.server],
                       live.is_uf.astype(int)),
              live.p95_eff * live.cores)
    plan = plan_migrations(cfg, live, st.chassis_of_server,
                           st.free_cores, rho_lv, 0.9,
                           np.array([True, False, False, False]),
                           max_moves_per_chassis=4)
    assert len(plan) >= 2
    dep, arr = plan.as_events()
    dep_t, arr_t = plan.paired_stamps(100.0)
    finals = []
    for n_hosts, deal in ((1, None), (3, "round-robin")):
        from repro.serve.featurizer import table_from_history
        cap = max(v.subscription for v in hist.vms) + 8
        pipe = ShardedServePipeline(
            svc, table_from_history(hist, labels, cap),
            device_state(st), cores_per_server=40,
            blades_per_chassis=12,
            config=ShardedServeConfig(batch_size=32, n_shards=4,
                                      n_ingest_hosts=n_hosts,
                                      planes=PlaneBundle(emergency=cfg)))
        # interleave all 2M rows in stamp order, dealt across hosts
        rows = sorted(
            [(dep_t[i], i, dep) for i in range(len(plan))]
            + [(arr_t[i], i, arr) for i in range(len(plan))])
        for k, (t, i, b) in enumerate(rows):
            pipe.depart_to(k % n_hosts, b.server[i:i + 1],
                           b.cores[i:i + 1], b.p95_eff[i:i + 1],
                           b.is_uf[i:i + 1], t=np.array([t]))
        pipe.flush()
        finals.append(pipe.global_state())
    for a, b in zip(finals[0], finals[1]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)


def test_token_pool_conserved_through_cap_migrate_uncap(serve_world):
    """A full emergency lifecycle on the sharded pipeline: cap events
    raise the alarm, the migration pair moves a critical VM across
    shards (credit + debit), the uncap sample lifts the cap — and the
    token pools balance to the admitted rho throughout."""
    from repro.serve import ShardedServeConfig, ShardedServePipeline
    from repro.serve.featurizer import table_from_history
    svc, hist, labels, _ = serve_world
    st, live = _mig_world()
    cfg = _cfg(lift_after_s=5.0)
    budget_w = 48 * 112.0 + 2000.0
    cap = max(v.subscription for v in hist.vms) + 8
    pipe = ShardedServePipeline(
        svc, table_from_history(hist, labels, cap), device_state(st),
        cores_per_server=40, blades_per_chassis=12,
        config=ShardedServeConfig(
            batch_size=32, n_shards=4,
            planes=PlaneBundle(
                cluster_budget=ResourceVector(watts=budget_w),
                emergency=cfg)))
    pool0 = rho_pool_from_budget(budget_w, 48, pipe.power_model)
    rho0 = float(np.asarray(pipe.global_state().rho_peak).sum())
    np.testing.assert_allclose(pipe.pool_left().sum(), pool0 - rho0,
                               rtol=1e-5)
    # cap: chassis 0 samples hot
    pipe.cap_to(0, [0], [2200.0], t=np.array([1.0]))
    assert pipe.alarms == 1
    assert (np.asarray(pipe.emergency.pstate) > 0).any()
    # migrate: paired events through the single queue
    rho_lv = np.zeros((4, 2))
    np.add.at(rho_lv, (np.asarray(st.chassis_of_server)[live.server],
                       live.is_uf.astype(int)),
              live.p95_eff * live.cores)
    plan = plan_migrations(cfg, live, st.chassis_of_server,
                           st.free_cores, rho_lv, 0.9,
                           np.array([True, False, False, False]))
    assert len(plan) > 0
    dep, arr = plan.as_events()
    dep_t, arr_t = plan.paired_stamps(2.0)
    for i in range(len(plan)):          # pairs in stamp order
        for b, ts in ((dep, dep_t), (arr, arr_t)):
            pipe.depart_to(0, b.server[i:i + 1], b.cores[i:i + 1],
                           b.p95_eff[i:i + 1], b.is_uf[i:i + 1],
                           t=ts[i:i + 1])
    pipe.flush()
    back = pipe.global_state()
    rho1 = float(np.asarray(back.rho_peak).sum())
    np.testing.assert_allclose(rho1, rho0, rtol=1e-5)     # moved, not lost
    np.testing.assert_allclose(pipe.pool_left().sum(), pool0 - rho1,
                               rtol=1e-4)
    # the moved rho actually changed chassis
    assert np.asarray(back.rho_peak)[0] < rho_lv.sum(-1)[0] - 1e-6
    # uncap: cool samples past the lift window restore nominal
    pipe.cap_to(0, [0], [1200.0], t=np.array([10.0]))
    pipe.cap_to(0, [0], [1200.0], t=np.array([20.0]))
    assert not (np.asarray(pipe.emergency.pstate) > 0).any()
    np.testing.assert_allclose(pipe.pool_left().sum(), pool0 - rho1,
                               rtol=1e-4)


# --- pipeline cap-event plumbing ------------------------------------------

@pytest.fixture(scope="module")
def serve_world():
    from repro.core import features as F
    from repro.core.predictor import train_service
    from repro.sim.telemetry import generate_population
    pop = generate_population(400, seed=0)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                       labels.astype(np.int64),
                       F.p95_bucket([v.p95_util for v in hist.vms]),
                       n_trees=12)
    return svc, hist, labels, arrivals


def test_cap_events_permutation_invariant_across_hosts(serve_world):
    """Dealing the same stamped power samples across different host
    counts must leave identical emergency state (kind-3 events obey
    the same merge contract as arrivals/departures)."""
    from repro.serve import ServeConfig, ServePipeline
    svc, hist, labels, _ = serve_world
    samples = [(float(t), c, p) for t, c, p in zip(
        np.arange(1.0, 13.0),
        [0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3],
        [2200.0, 1300.0, 2400.0, 1350.0, 1250.0, 2300.0,
         1200.0, 2250.0, 2350.0, 1280.0, 1320.0, 1400.0])]
    states = []
    for n_hosts in (1, 3):
        pipe = ServePipeline.from_history(
            svc, hist, labels, n_servers=48, cores_per_server=40,
            blades_per_chassis=12,
            config=ServeConfig(batch_size=32, n_ingest_hosts=n_hosts,
                               planes=PlaneBundle(emergency=_cfg())))
        for k, (t, c, p) in enumerate(samples):
            pipe.cap_to(k % n_hosts, [c], [p], t=np.array([t]))
        pipe.flush()
        states.append(pipe.emergency)
    for a, b in zip(states[0], states[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cap_to_requires_emergency_cfg(serve_world):
    from repro.serve import ServePipeline
    svc, hist, labels, _ = serve_world
    pipe = ServePipeline.from_history(
        svc, hist, labels, n_servers=48, cores_per_server=40,
        blades_per_chassis=12)
    with pytest.raises(ValueError):
        pipe.cap_to(0, [0], [2000.0])


def test_mitigation_due_and_dwell_reset():
    cfg = _cfg(BUDGET_2X, dwell_s=20.0)
    rho = np.array([[40.0, 400.0]])       # critical-heavy: UF capped
    st = init_emergency(1, xp=np, dtype=np.float64)
    for t in (0.0, 10.0, 25.0):
        st, out = emergency_step(cfg, st, rho, 0.95, t, np)
        assert out.alarm[0]
    assert mitigation_due(cfg, st, np)[0]
    from repro.serve import reset_dwell
    st = reset_dwell(st, np.array([True]), np)
    assert not mitigation_due(cfg, st, np)[0]
