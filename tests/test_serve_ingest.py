"""Cross-host ingest (`repro.serve.ingest`) — merge determinism,
watermark gating, and decision equivalence through the serve layers.

The contract under test (docs/ingest.md):

  * the k-way merge is *exactly* the (t, host_id, seq) order an
    oracle lexsort of the concatenated streams produces — but built
    from per-host sorted windows, never a global sort;
  * with globally unique stamps the merged order (and every placement
    decision downstream) is invariant to how events were dealt across
    host queues;
  * `poll` releases only events no host can still get in front of
    (the fleet watermark); `drain` releases everything;
  * a 1-host pipeline is decision-identical to the single-queue path
    it replaced, and `simulate(backend='serve-sharded',
    n_ingest_hosts=1)` is decision-identical to the pre-ingest
    backend — for any host count, in fact, because the sim stamps
    arrivals uniquely.
"""
import numpy as np
import pytest

from repro.core import features as F
from repro.core.placement import SchedulerPolicy
from repro.core.predictor import train_service
from repro.serve import (ARRIVAL, DEPARTURE, DepartureBatch, HostQueue,
                         IngestMux, PlaneBundle, ResourceVector,
                         ServeConfig, ServePipeline,
                         ShardedServeConfig, ShardedServePipeline,
                         consume_departures, device_state, kway_merge,
                         remove_batch, shard_state, split_departures,
                         unshard_state)
from repro.sim.telemetry import (arrival_batch, generate_population,
                                 merge_streams, split_streams)
from tests.test_serve_sharded import _batch, _loaded_state


def _oracle_order(stamps_by_host):
    t = np.concatenate([np.asarray(s, float) for s in stamps_by_host])
    host = np.concatenate([np.full(len(s), h, np.int32)
                           for h, s in enumerate(stamps_by_host)])
    seq = np.concatenate([np.arange(len(s)) for s in stamps_by_host])
    order = np.lexsort((seq, host, t))
    return host[order], seq[order]


# --- k-way merge ----------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_kway_merge_matches_lexsort_oracle(seed):
    rng = np.random.default_rng(seed)
    # integer stamps force plenty of cross-host ties -> (host, seq)
    # tie-breaking is actually exercised
    stamps = [np.sort(rng.integers(0, 30, rng.integers(0, 50)))
              .astype(float) for _ in range(5)]
    got_h, got_i = kway_merge(stamps)
    want_h, want_i = _oracle_order(stamps)
    np.testing.assert_array_equal(got_h, want_h)
    np.testing.assert_array_equal(got_i, want_i)


def test_kway_merge_empty_and_single():
    h, i = kway_merge([])
    assert len(h) == 0 and len(i) == 0
    h, i = kway_merge([np.array([1.0, 2.0]), np.empty(0)])
    np.testing.assert_array_equal(h, [0, 0])
    np.testing.assert_array_equal(i, [0, 1])


def test_merged_order_invariant_to_host_dealing():
    """Unique stamps: however arrivals are dealt across hosts, the
    merged stream is the same."""
    rng = np.random.default_rng(3)
    t = np.sort(rng.uniform(0, 100, 64))
    for n_hosts in (2, 4):
        for perm_seed in range(3):
            deal = np.random.default_rng(perm_seed) \
                .integers(0, n_hosts, len(t))
            rows = [np.flatnonzero(deal == h) for h in range(n_hosts)]
            mh, mi = kway_merge([t[r] for r in rows])
            merged_global = np.array(
                [rows[h][i] for h, i in zip(mh, mi)])
            np.testing.assert_array_equal(merged_global,
                                          np.arange(len(t)))


# --- host queues + watermark ----------------------------------------------

def _dep(n):
    return DepartureBatch(np.arange(n, dtype=np.int32),
                          np.full(n, 2.0, np.float32),
                          np.full(n, 0.5, np.float32),
                          np.ones(n, bool))


def test_host_queue_rejects_non_monotonic_stamps():
    pop = generate_population(8, seed=0)
    q = HostQueue(0)
    q.push_arrivals(arrival_batch(pop, np.arange(4)),
                    t=np.array([1.0, 2.0, 2.0, 3.0]))   # ties ok
    with pytest.raises(ValueError):                     # not after last
        q.push_arrivals(arrival_batch(pop, np.arange(4, 8)),
                        t=np.array([3.0, 4.0, 5.0, 6.0]))
    with pytest.raises(ValueError):                     # decreasing
        q.push_arrivals(arrival_batch(pop, np.arange(4, 8)),
                        t=np.array([9.0, 8.0, 10.0, 11.0]))
    with pytest.raises(ValueError):                     # wrong length
        q.push_arrivals(arrival_batch(pop, np.arange(4, 8)),
                        t=np.array([9.0, 10.0]))


def test_watermark_gates_poll_and_close_releases():
    pop = generate_population(24, seed=1)
    mux = IngestMux(3)
    mux.submit_to(0, arrival_batch(pop, np.arange(8)),
                  t=np.arange(1.0, 9.0))
    assert len(mux.poll()) == 0          # hosts 1,2 never pushed
    mux.submit_to(1, arrival_batch(pop, np.arange(8, 16)),
                  t=np.arange(0.5, 8.5))
    assert len(mux.poll()) == 0          # host 2 still at -inf
    mux.submit_to(2, arrival_batch(pop, np.arange(16, 20)),
                  t=np.array([3.0, 3.5, 4.0, 4.5]))
    ev = mux.poll()                      # watermark = min(8, 7.5, 4.5)
    assert len(ev) > 0
    assert ev.t.max() <= 4.5
    assert (np.diff(ev.t) >= 0).all()
    assert mux.n_pending > 0
    mux.close(2)                         # watermark -> min(8, 7.5)
    ev2 = mux.poll()
    assert ev2.t.max() <= 7.5
    rest = mux.drain()                   # everything, watermark ignored
    assert mux.n_pending == 0
    assert len(ev) + len(ev2) + len(rest) == 20


def test_heartbeat_unblocks_idle_host():
    """An idle host stalls the watermark; a heartbeat (explicit, or an
    empty stamped push) advances its clock without events."""
    pop = generate_population(8, seed=5)
    mux = IngestMux(2)
    mux.submit_to(0, arrival_batch(pop, np.arange(4)),
                  t=np.arange(1.0, 5.0))
    assert len(mux.poll()) == 0              # host 1 idle at -inf
    mux.heartbeat(1, 3.0)
    ev = mux.poll()
    assert list(ev.t) == [1.0, 2.0, 3.0]
    mux.submit_to(1, arrival_batch(pop, np.arange(4, 4)), t=10.0)
    assert len(mux.poll()) == 1              # empty push == heartbeat
    with pytest.raises(ValueError):          # clocks only move forward
        mux.heartbeat(1, 5.0)


def test_departures_merge_at_their_stamped_position():
    pop = generate_population(8, seed=2)
    mux = IngestMux(2)
    mux.submit_to(0, arrival_batch(pop, np.arange(4)),
                  t=np.array([1.0, 2.0, 5.0, 6.0]))
    mux.depart_to(1, _dep(2), t=np.array([3.0, 4.0]))
    ev = mux.drain()
    assert list(ev.kind) == [ARRIVAL, ARRIVAL, DEPARTURE, DEPARTURE,
                             ARRIVAL, ARRIVAL]
    runs = list(ev.runs())
    assert runs == [(ARRIVAL, 0, 2), (DEPARTURE, 0, 2), (ARRIVAL, 2, 4)]
    np.testing.assert_array_equal(ev.departures.server, [0, 1])


def test_merged_column_dtypes_survive_any_host_mix():
    """Column dtypes are contractual (jitted kernels + integer
    indexing downstream): they must survive even when the
    first-contributing host has zero rows of a kind."""
    pop = generate_population(8, seed=4)
    mux = IngestMux(2)
    mux.depart_to(0, _dep(3), t=np.array([1.0, 2.0, 3.0]))   # deps only
    mux.submit_to(1, arrival_batch(pop, np.arange(4)),
                  t=np.array([1.5, 2.5, 3.5, 4.5]))
    ev = mux.drain()
    assert ev.arrivals.subscription.dtype == np.int32
    assert ev.arrivals.vm_type_idx.dtype == np.int32
    assert ev.arrivals.user_facing.dtype == bool
    assert ev.arrivals.cores.dtype == np.float32
    assert ev.departures.server.dtype == np.int32
    assert ev.departures.is_uf.dtype == bool
    # empty polls keep typed columns too
    empty = IngestMux(2).poll()
    assert empty.arrivals.subscription.dtype == np.int32
    assert empty.departures.server.dtype == np.int32


def test_mux_agrees_with_merge_streams_oracle():
    pop = generate_population(120, seed=3)
    streams = split_streams(pop, 4, 16, arrival_rate_per_s=50.0, seed=7)
    mux = IngestMux(4)
    for h, chunks in enumerate(streams):
        for stamps, batch in chunks:
            mux.submit_to(h, batch, t=stamps)
    ev = mux.drain()
    t, host, merged = merge_streams(streams)
    np.testing.assert_array_equal(ev.t, t)
    np.testing.assert_array_equal(ev.host, host)
    for f in ("subscription", "cores", "p95_util"):
        np.testing.assert_array_equal(getattr(ev.arrivals, f),
                                      getattr(merged, f))


# --- pipeline integration -------------------------------------------------

@pytest.fixture(scope="module")
def world():
    pop = generate_population(400, seed=0)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=8)
    return {"svc": svc, "hist": hist, "labels": labels,
            "arrivals": arrivals}


_KW = dict(n_servers=48, cores_per_server=40, blades_per_chassis=12)


def _pipe(world, **cfg):
    return ServePipeline.from_history(
        world["svc"], world["hist"], world["labels"],
        config=ServeConfig(batch_size=16, **cfg), **_KW)


def test_one_host_submit_is_single_queue_special_case(world):
    a, b = _pipe(world), _pipe(world)
    batch = arrival_batch(world["arrivals"], np.arange(40))
    ra = a.submit(batch) + [a.flush()]
    rb = b.submit_to(0, batch) + [b.flush()]
    assert len(ra) == len(rb)
    for x, y in zip(ra, rb):
        np.testing.assert_array_equal(x.server, y.server)
    # multi-host pipelines must refuse the ambiguous single-queue API
    multi = _pipe(world, n_ingest_hosts=2)
    with pytest.raises(ValueError):
        multi.submit(batch)
    with pytest.raises(ValueError):          # same for immediate depart
        multi.depart(np.array([0]), np.array([2.0]), np.array([0.5]),
                     np.array([True]))


def test_multi_host_decisions_match_merged_single_host(world):
    """Feed N per-host streams; decisions must equal a 1-host pipeline
    fed the timestamp-merged stream — and be invariant to permuting
    which queue got which stream."""
    pop = F.Population(vms=world["arrivals"].vms[:96])
    streams = split_streams(pop, 4, 8, arrival_rate_per_s=20.0, seed=5)
    _, _, merged = merge_streams(streams)
    single = _pipe(world)
    want = [r.server for r in single.submit(merged)]
    tail = single.flush()
    if tail is not None:
        want.append(tail.server)
    want = np.concatenate(want)
    for host_perm in (np.arange(4), np.array([2, 0, 3, 1])):
        multi = _pipe(world, n_ingest_hosts=4)
        results = []
        chunk_iters = [list(streams[h]) for h in range(4)]
        for j in range(max(map(len, chunk_iters))):
            for h in range(4):
                if j < len(chunk_iters[h]):
                    stamps, batch = chunk_iters[h][j]
                    results += multi.submit_to(int(host_perm[h]),
                                               batch, t=stamps)
        tail = multi.flush()
        if tail is not None:
            results.append(tail)
        got = np.concatenate([r.server for r in results])
        np.testing.assert_array_equal(got, want)


def test_sharded_departure_stream_credits_pool(world):
    pipe = ShardedServePipeline.from_history(
        world["svc"], world["hist"], world["labels"],
        config=ShardedServeConfig(
            batch_size=16, n_shards=4,
            planes=PlaneBundle(cluster_budget=ResourceVector(
                watts=48 * 112.0 + 800.0))), **_KW)
    res = pipe.submit_to(0, arrival_batch(world["arrivals"],
                                          np.arange(32)),
                         t=np.arange(1.0, 33.0))
    srv = np.concatenate([r.server for r in res])
    adm = srv[srv >= 0][:4]
    assert len(adm) == 4
    cores = np.full(4, 2.0)
    p95 = np.full(4, 0.5)
    pool0 = pipe.pool_left().sum()
    out = pipe.depart_to(0, adm, cores, p95, np.ones(4, bool), t=40.0)
    assert out == []                     # no arrivals released
    np.testing.assert_allclose(pipe.pool_left().sum() - pool0,
                               (cores * p95).sum(), rtol=1e-5)


# --- sharded departure batches (in-scan credit) ---------------------------

def test_split_consume_departures_match_unsharded_remove():
    st = _loaded_state(4)
    cores, uf, p95, _ = _batch(8, 24)
    servers = np.random.default_rng(0).integers(-2, 48, 24)
    sharded = shard_state(device_state(st), 4, pool_total=100.0)
    parts = split_departures(sharded, servers, cores, p95, uf)
    # every live departure lands on exactly one shard
    assert (parts[0] >= 0).sum() == (servers >= 0).sum()
    out = consume_departures(sharded, *parts)
    want = remove_batch(device_state(st), servers, cores, p95, uf)
    back = unshard_state(out)
    np.testing.assert_allclose(np.asarray(back.free_cores),
                               np.asarray(want.free_cores), atol=1e-4)
    np.testing.assert_allclose(np.asarray(back.rho_peak),
                               np.asarray(want.rho_peak), atol=1e-4)
    live = servers >= 0
    credit = (p95[live] * cores[live]).sum()
    np.testing.assert_allclose(np.asarray(out.pool)[:, 0].sum(),
                               100.0 + credit, rtol=1e-5)


# --- scheduler-sim backend ------------------------------------------------

def test_sim_ingest_one_host_identical_and_host_count_invariant():
    """backend='serve-sharded' with n_ingest_hosts=1 reproduces the
    pre-ingest path trace-for-trace; the sim's unique stamps make any
    host count identical too."""
    from repro.sim.scheduler_sim import (PredictionChannel,
                                         ServeBackendSpec, SimSpec,
                                         simulate)
    traces = []
    for hosts in (1, 1, 4):
        tr = []
        m = simulate(SchedulerPolicy(alpha=0.8),
                     PredictionChannel("ml"),
                     SimSpec(days=0.3, seed=0,
                             serve=ServeBackendSpec(
                                 backend="serve-sharded", shards=2,
                                 ingest_hosts=hosts)),
                     trace=tr)
        traces.append((tr, m.failure_rate))
    assert traces[0] == traces[1] == traces[2]
    with pytest.raises(ValueError):
        ServeBackendSpec(backend="serve-sharded", ingest_hosts=0)
    with pytest.raises(ValueError):      # knob is serve-sharded-only;
        simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                 SimSpec(days=0.1, seed=0,
                         serve=ServeBackendSpec(backend="serve",
                                                ingest_hosts=4)))
