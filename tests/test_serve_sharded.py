"""Sharded serve placement (`repro.serve.sharding`) — equivalence and
invariants.

The contract under test (docs/sharding.md):

  * 1 shard is *decision-identical* to the unsharded serve path (and
    therefore, in x64, to the event-driven scheduler oracle);
  * N shards never exceed the global watt budget their token pools
    encode, whatever the spillover traffic does;
  * the whole protocol — routing, reserve, spillover commit — is a
    deterministic function of the batch under a fixed seed;
  * the vmap and shard_map executions of the per-shard scans agree
    (the shard_map leg needs >= 4 devices:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — CI's
    sharded smoke job; it skips elsewhere).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import features as F
from repro.core.placement import ClusterState, SchedulerPolicy
from repro.core.predictor import train_service
from repro.serve import (FAIL_TOKENS, PlaneBundle, ResourceVector,
                         ServeConfig, ServePipeline,
                         ShardedServeConfig, ShardedServePipeline,
                         chassis_to_shard, device_state, featurize_batch,
                         place_batch, place_group_sharded,
                         remove_sharded, rho_pool_from_budget,
                         route_shard, shard_mesh, shard_state,
                         shard_table, unshard_state)
from repro.sim.telemetry import arrival_batch, generate_population

#: Policies the fig-7 sweep exercises through the serve backends.
POLICIES = [SchedulerPolicy(alpha=0.8),
            SchedulerPolicy(alpha=0.0),
            SchedulerPolicy(alpha=0.8, packing_weight=0.0),
            SchedulerPolicy(use_power_rule=False)]


def _loaded_state(seed, n_servers=48, per_chassis=4, cores=40, n=120):
    rng = np.random.default_rng(seed)
    st = ClusterState(n_servers=n_servers, cores_per_server=cores,
                      chassis_of_server=np.arange(n_servers) // per_chassis,
                      n_chassis=n_servers // per_chassis)
    for _ in range(n):
        srv = int(rng.integers(0, n_servers))
        c = int(rng.integers(1, 8))
        if st.free_cores[srv] >= c:
            st.place(srv, c, float(rng.uniform(0, 1)),
                     bool(rng.random() < 0.5))
    return st


def _batch(seed, b=32):
    rng = np.random.default_rng(seed)
    return (rng.choice([1, 2, 4, 8], b).astype(np.float64),
            rng.random(b) < 0.4, rng.uniform(0.05, 1.0, b),
            np.ones(b, bool))


# --- layout ---------------------------------------------------------------

def test_chassis_to_shard_contiguous_blocks():
    m = chassis_to_shard(12, 4)
    np.testing.assert_array_equal(m, np.repeat(np.arange(4), 3))
    with pytest.raises(ValueError):
        chassis_to_shard(12, 5)


def test_route_shard_rounds_are_bijections():
    b, n = 64, 4
    home = route_shard(b, n)
    np.testing.assert_array_equal(home, np.arange(b) % n)
    for rnd in range(n):
        t = route_shard(b, n, rnd)
        # each round moves every home shard to a distinct target, so
        # per-shard load stays exactly b/n and slots cannot overflow
        assert all(len(set(t[home == h])) == 1 for h in range(n))
        assert len(set((t[home == h][0] for h in range(n)))) == n


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_shard_unshard_roundtrip(n_shards):
    dst = device_state(_loaded_state(0))
    back = unshard_state(shard_state(dst, n_shards))
    for a, b in zip(back, dst):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rho_pool_from_budget_matches_power_model():
    from repro.core.power_model import F_MAX, idle_power
    from repro.core.power_model import ServerPowerModel
    m = ServerPowerModel()
    w = 48 * float(idle_power(F_MAX)) + m.p_dyn_per_core * 37.5
    assert rho_pool_from_budget(w, 48, m) == pytest.approx(37.5)
    assert np.isinf(rho_pool_from_budget(None, 48))


# --- 1-shard decision identity --------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_one_shard_identical_to_place_batch_x64(policy):
    """The sharded protocol with one shard must reproduce the unsharded
    scan decision-for-decision (the same configs the fig-7 serve
    equivalence suite uses), including the final state."""
    st = _loaded_state(3, n_servers=36, per_chassis=12, n=200)
    cores, uf, p95, valid = _batch(7, 48)
    with jax.experimental.enable_x64():
        dst, srvs = place_batch(device_state(st, jnp.float64), cores,
                                uf, p95, valid,
                                np.full(st.n_chassis, np.inf), policy,
                                st.cores_per_server)
        want = [int(x) for x in np.asarray(srvs)]
        shd = shard_state(device_state(st, jnp.float64), 1)
        shd, got, info = place_group_sharded(shd, cores, uf, p95, valid,
                                             policy,
                                             st.cores_per_server)
        back = unshard_state(shd)
        np.testing.assert_array_equal(np.asarray(back.free_cores),
                                      np.asarray(dst.free_cores))
        np.testing.assert_array_equal(np.asarray(back.rho_peak),
                                      np.asarray(dst.rho_peak))
    assert list(got) == want
    assert info["spilled"] == 0


def test_one_shard_sim_backend_reproduces_event_oracle():
    """backend='serve-sharded' at 1 shard == backend='serve' == the
    event-driven oracle on the fig-7 cluster, trace-for-trace."""
    from repro.sim.scheduler_sim import (PredictionChannel,
                                         ServeBackendSpec, SimSpec,
                                         simulate)
    tr_e, tr_s, tr_sh = [], [], []
    e = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                 SimSpec(days=0.6, seed=0), trace=tr_e)
    simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
             SimSpec(days=0.6, seed=0,
                     serve=ServeBackendSpec(backend="serve")),
             trace=tr_s)
    sh = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                  SimSpec(days=0.6, seed=0,
                          serve=ServeBackendSpec(
                              backend="serve-sharded", shards=1)),
                  trace=tr_sh)
    assert tr_e == tr_s == tr_sh
    assert e.failure_rate == sh.failure_rate
    assert e.empty_server_ratio == sh.empty_server_ratio


def test_one_shard_pipeline_identical_to_unsharded(serve_world):
    svc, hist, labels, arrivals = serve_world
    kw = dict(n_servers=48, cores_per_server=40, blades_per_chassis=12)
    base = ServePipeline.from_history(
        svc, hist, labels, config=ServeConfig(batch_size=32), **kw)
    shp = ShardedServePipeline.from_history(
        svc, hist, labels,
        config=ShardedServeConfig(batch_size=32, n_shards=1), **kw)
    b = arrival_batch(arrivals, np.arange(64))
    r0, r1 = base.serve(b), shp.serve(b)
    np.testing.assert_array_equal(r0.server, r1.server)
    np.testing.assert_array_equal(r0.workload_type, r1.workload_type)


# --- N-shard invariants ---------------------------------------------------

def test_global_watt_budget_never_exceeded():
    """With 4 shards and a deliberately tiny global pool, the sum of
    admitted p95*cores must stay under the pool however spillover
    shuffles arrivals, and the shortfall must be reported as
    FAIL_TOKENS."""
    st = _loaded_state(1)
    cores, uf, p95, valid = _batch(2, 64)
    pool_total = 15.0
    with jax.experimental.enable_x64():
        shd = shard_state(device_state(st, jnp.float64), 4,
                          pool_total=pool_total)
        shd, got, _ = place_group_sharded(shd, cores, uf, p95, valid,
                                          SchedulerPolicy(alpha=0.8),
                                          st.cores_per_server)
    used = (p95 * cores)[got >= 0].sum()
    assert used <= pool_total + 1e-9
    assert (got == FAIL_TOKENS).any()
    # the pool balance accounts exactly for what was admitted (the
    # watts axis — the unbudgeted cores/GB axes stay +inf)
    assert np.asarray(shd.pool)[:, 0].sum() == \
        pytest.approx(pool_total - used)


def test_budget_invariant_across_groups_and_departures():
    """The sim's serve-sharded backend recomputes the pool net of
    live commitments each group; across a multi-group run with
    departures the fleet never exceeds the cluster budget."""
    from repro.sim.scheduler_sim import (PredictionChannel,
                                         ServeBackendSpec, SimSpec,
                                         simulate)
    from repro.core.power_model import (F_MAX, ServerPowerModel, idle_power)
    from repro.core.resources import ResourceVector
    n_servers = 720
    budget = n_servers * float(idle_power(F_MAX)) \
        + ServerPowerModel().p_dyn_per_core * 400.0
    m = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                 SimSpec(days=1.0, seed=0, serve=ServeBackendSpec(
                     backend="serve-sharded", shards=4,
                     cluster_budget=ResourceVector(watts=budget))))
    # a 400-rho allowance on this arrival rate forces token rejections
    # while the invariant keeps every accepted watt under budget
    assert m.failure_rate > 0.0
    assert m.placements > 0


def test_spillover_deterministic_and_admits_cross_shard():
    """Home shard 0's chassis are pre-filled, so its arrivals must
    spill; under a fixed seed two runs agree decision-for-decision and
    spilled arrivals land on foreign shards."""
    def build():
        st = _loaded_state(0, n_servers=48, per_chassis=4, n=0)
        for srv in range(12):            # shard 0 owns servers 0-11
            st.place(srv, 40, 0.5, True)
        return st
    cores, uf, p95, valid = _batch(5, 32)
    policy = SchedulerPolicy(alpha=0.8)
    outs = []
    for _ in range(2):
        shd = shard_state(device_state(build()), 4)
        shd, got, info = place_group_sharded(shd, cores, uf, p95,
                                             valid, policy, 40)
        outs.append((got, info))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    # info carries the (R,) per-resource draw — compare it per key
    for k, v in outs[0][1].items():
        np.testing.assert_array_equal(v, outs[1][1][k], err_msg=k)
    assert outs[0][1]["spilled"] > 0
    assert outs[0][1]["spill_admitted"] > 0
    # shard 0's home arrivals (indices 0 mod 4) were admitted elsewhere
    home0 = outs[0][0][route_shard(32, 4) == 0]
    assert (home0[home0 >= 0] >= 12).all()


def test_spillover_reaches_any_feasible_server():
    """Sharding must not invent capacity failures: when exactly one
    server fleet-wide can host an arrival, the spillover rounds find
    it regardless of the arrival's home shard."""
    st = _loaded_state(0, n_servers=16, per_chassis=4, n=0)
    for srv in range(16):
        # server 13 keeps 10 free cores (room for exactly one 8-core
        # arrival); everywhere else 2 free
        st.place(srv, 30 if srv == 13 else 38, 0.5, True)
    cores = np.full(4, 8.0)
    uf = np.ones(4, bool)
    p95 = np.full(4, 0.5)
    shd = shard_state(device_state(st), 4)
    shd, got, info = place_group_sharded(
        shd, cores, uf, p95, np.ones(4, bool),
        SchedulerPolicy(alpha=0.8), 40)
    assert (got == 13).sum() == 1        # exactly one winner
    assert (got < 0).sum() == 3          # the rest genuinely don't fit


def test_four_shard_failure_rate_tracks_oracle():
    """Objective regret, not feasibility regret: on the fig-7 cluster
    an unbudgeted 4-shard run must not inflate deployment failures
    relative to the event oracle."""
    from repro.sim.scheduler_sim import (PredictionChannel,
                                         ServeBackendSpec, SimSpec,
                                         simulate)
    e = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                 SimSpec(days=0.6, seed=0))
    s4 = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                  SimSpec(days=0.6, seed=0,
                          serve=ServeBackendSpec(
                              backend="serve-sharded", shards=4)))
    assert abs(s4.failure_rate - e.failure_rate) <= 0.02


def test_remove_sharded_roundtrip_restores_state_and_pool():
    st = _loaded_state(6)
    pool_total = 200.0
    with jax.experimental.enable_x64():
        shd0 = shard_state(device_state(st, jnp.float64), 4,
                           pool_total=pool_total)
        cores, uf, p95, valid = _batch(9, 16)
        shd, got, _ = place_group_sharded(shd0, cores, uf, p95, valid,
                                          SchedulerPolicy(alpha=0.8),
                                          st.cores_per_server)
        shd = remove_sharded(shd, got, cores, p95, uf)
        # scatter-add removal may reassociate sums of co-located VMs;
        # exactness is to the last ulp, not bitwise
        for a, b in zip(unshard_state(shd), unshard_state(shd0)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-9)
        np.testing.assert_allclose(np.asarray(shd.pool)[:, 0].sum(),
                                   pool_total)


# --- sharded pipeline -----------------------------------------------------

@pytest.fixture(scope="module")
def serve_world():
    pop = generate_population(500, seed=0)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=12)
    return svc, hist, labels, arrivals


def test_sharded_pipeline_end_to_end(serve_world):
    svc, hist, labels, arrivals = serve_world
    pipe = ShardedServePipeline.from_history(
        svc, hist, labels, n_servers=48, cores_per_server=40,
        blades_per_chassis=12,
        config=ShardedServeConfig(
            batch_size=32, n_shards=4,
            planes=PlaneBundle(cluster_budget=ResourceVector(
                watts=48 * 112.0 + 800.0))))
    b = arrival_batch(arrivals, np.arange(96))
    res = pipe.serve(b)
    assert len(res.server) == 96
    assert res.n_admitted + res.n_capacity_rejected \
        + res.n_power_rejected + res.n_token_rejected == 96
    # token accounting: pool spent == admitted rho, across all shards
    pool0 = rho_pool_from_budget(48 * 112.0 + 800.0, 48,
                                 pipe.power_model)
    rho = float(np.asarray(pipe.global_state().rho_peak).sum())
    assert rho <= pool0 + 1e-4
    np.testing.assert_allclose(pipe.pool_left().sum(), pool0 - rho,
                               atol=1e-4)


def test_warm_start_pipeline_nets_committed_rho(serve_world):
    """A pipeline built over a cluster with rho already committed must
    seed its token pool with the *remaining* allowance, so warm starts
    cannot admit a full budget on top of existing load."""
    from repro.core.placement import ClusterState
    from repro.serve.featurizer import table_from_history
    svc, hist, labels, _ = serve_world
    st = ClusterState(n_servers=48, cores_per_server=40,
                      chassis_of_server=np.arange(48) // 12, n_chassis=4)
    st.place(0, 20, 0.9, True)            # 18 rho-units pre-committed
    budget_w = 48 * 112.0 + 800.0
    cap = max(v.subscription for v in hist.vms) + 8
    pipe = ShardedServePipeline(
        svc, table_from_history(hist, labels, cap), device_state(st),
        cores_per_server=40, blades_per_chassis=12,
        config=ShardedServeConfig(
            batch_size=32, n_shards=4,
            planes=PlaneBundle(
                cluster_budget=ResourceVector(watts=budget_w))))
    pool = rho_pool_from_budget(budget_w, 48, pipe.power_model)
    np.testing.assert_allclose(pipe.pool_left().sum(), pool - 18.0,
                               rtol=1e-5)


def test_sharded_batch_size_must_divide(serve_world):
    svc, hist, labels, _ = serve_world
    with pytest.raises(ValueError):
        ShardedServePipeline.from_history(
            svc, hist, labels, n_servers=48, cores_per_server=40,
            blades_per_chassis=12,
            config=ShardedServeConfig(batch_size=30, n_shards=4))
    st = _loaded_state(0)
    with pytest.raises(ValueError):
        place_group_sharded(shard_state(device_state(st), 4),
                            *_batch(0, 30), SchedulerPolicy(), 40)


# --- shard_map execution (needs a multi-device runtime) -------------------

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


@needs_devices
def test_shard_map_matches_vmap():
    """The mesh execution must agree with the single-device vmap twin
    decision-for-decision (identical per-shard arithmetic)."""
    st = _loaded_state(2)
    cores, uf, p95, valid = _batch(3, 32)
    policy = SchedulerPolicy(alpha=0.8)
    outs = []
    for mesh in (None, shard_mesh(4)):
        shd = shard_state(device_state(st), 4, pool_total=120.0)
        shd, got, info = place_group_sharded(shd, cores, uf, p95,
                                             valid, policy, 40,
                                             mesh=mesh)
        outs.append((got, np.asarray(shd.pool)))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=1e-6)


@needs_devices
def test_sharded_pipeline_on_mesh(serve_world):
    svc, hist, labels, arrivals = serve_world
    pipe = ShardedServePipeline.from_history(
        svc, hist, labels, n_servers=48, cores_per_server=40,
        blades_per_chassis=12,
        config=ShardedServeConfig(batch_size=32, n_shards=4,
                                  use_shard_map=True))
    assert pipe.mesh is not None
    res = pipe.serve(arrival_batch(arrivals, np.arange(64)))
    assert res.n_admitted > 0


@needs_devices
def test_shard_table_featurize_parity(serve_world):
    svc, hist, labels, arrivals = serve_world
    from repro.serve import table_from_history
    cap = max(v.subscription for v in hist.vms) + 8
    table = table_from_history(hist, labels, cap)
    sharded = shard_table(table, shard_mesh(4))
    assert sharded.capacity % 4 == 0
    b = arrival_batch(arrivals, np.arange(32))
    np.testing.assert_allclose(
        np.asarray(featurize_batch(sharded, b)),
        np.asarray(featurize_batch(table, b)), atol=1e-6)
