"""Substrate tests: loss, optimizers, data pipeline, checkpointing,
fault tolerance, power-control integration, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models.loss import chunked_ce
from repro.optim import adafactor, adamw
from repro.optim.grad_compress import (compress_decompress,
                                       make_error_feedback)
from repro.runtime.fault_tolerance import (FaultToleranceConfig,
                                           FaultTolerantLoop)
from repro.runtime.power_control import ChassisPowerSim, JobSpec


# --- loss ------------------------------------------------------------------

def test_chunked_ce_matches_direct():
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(0, 1, (2, 64, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 1, (16, 50)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 50, (2, 64)), jnp.int32)
    out = float(chunked_ce(h, w, y, chunk=16))
    logits = np.asarray(h @ w, np.float64)
    lse = np.log(np.exp(logits - logits.max(-1, keepdims=True))
                 .sum(-1)) + logits.max(-1)
    gold = np.take_along_axis(logits, np.asarray(y)[..., None],
                              -1)[..., 0]
    expect = float((lse - gold).mean())
    assert out == pytest.approx(expect, rel=1e-4)


def test_chunked_ce_ignores_negative_labels():
    h = jnp.ones((1, 8, 4))
    w = jnp.eye(4)
    y = jnp.asarray([[0, 1, -1, -1, 2, 3, -1, 0]], jnp.int32)
    out = float(chunked_ce(h, w, y, chunk=4))
    assert np.isfinite(out)


# --- optimizers --------------------------------------------------------------

@pytest.mark.parametrize("make_opt", [adamw, adafactor])
def test_optimizer_descends_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray(np.ones((4, 8), np.float32) * 3.0)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, gnorm = opt.update(grads, state, params, 0.05)
    assert float(loss(params)) < 0.5 * l0
    assert np.isfinite(float(gnorm))


def test_grad_compression_bounded_error():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(0, 1, (64, 64)).astype(np.float32))}
    out = compress_decompress(g)
    err = np.abs(np.asarray(out["a"]) - np.asarray(g["a"]))
    assert err.max() <= float(np.abs(np.asarray(g["a"])).max()) / 127.0 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    init, apply = make_error_feedback()
    g = {"a": jnp.asarray(np.full((16,), 0.001, np.float32))}
    err = init(g)
    total = np.zeros(16, np.float32)
    for _ in range(100):
        comp, err = apply(g, err)
        total += np.asarray(comp["a"])
    # accumulated compressed sum approaches the true sum (error feedback)
    np.testing.assert_allclose(total, 0.1, rtol=0.15)


# --- data --------------------------------------------------------------------

def test_data_deterministic_and_restart_safe():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    a = SyntheticLM(cfg).batch_at(12)
    b = SyntheticLM(cfg).batch_at(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"][:, :-1],
                                  a["tokens"][:, 1:])


def test_prefetcher_in_order():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2, seed=1)
    src = SyntheticLM(cfg)
    pf = Prefetcher(src, start_step=5, depth=2)
    steps = [pf.next()[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


# --- checkpoint ----------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"params": {"w": jnp.asarray(np.arange(6, dtype=np.float32)
                                        .reshape(2, 3)),
                       "b": jnp.asarray(np.ones(3, np.float32))},
            "step_scale": jnp.asarray(np.float32(2.5)),
            "bf16": jnp.ones((4,), jnp.bfloat16) * 1.5}
    ck.save(10, tree)
    restored, step = ck.restore(tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert restored["bf16"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["bf16"], np.float32), 1.5)


def test_checkpoint_uncommitted_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones(3)}
    ck.save(5, tree)
    # fake a partial write
    os.makedirs(tmp_path / "step_00000009")
    assert ck.latest_step() == 5


def test_checkpoint_rotation(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    tree = {"w": jnp.ones(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]


# --- fault tolerance -------------------------------------------------------------

def test_fault_tolerant_loop_recovers(tmp_path):
    ck = Checkpointer(str(tmp_path))
    cfg = FaultToleranceConfig(checkpoint_every=5,
                               inject_failure_rate=0.15)
    loop = FaultTolerantLoop(cfg, ck, rng_seed=3)

    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {"x": float(state["x"])}

    def batch_fn(step):
        return 1.0

    state, history = loop.run({"x": jnp.asarray(0.0)}, step_fn, batch_fn,
                              n_steps=40)
    assert loop.state.restarts > 0                  # failures did happen
    assert float(state["x"]) == 40.0                # and were recovered


def test_straggler_detection(tmp_path):
    ck = Checkpointer(str(tmp_path))
    cfg = FaultToleranceConfig(straggler_factor=2.0,
                               straggler_patience=2)
    loop = FaultTolerantLoop(cfg, ck)
    hits = []
    loop.on_straggler = lambda s: hits.append(s.step)
    for dt in [0.1] * 20 + [0.5] * 4:
        loop._track_straggler(dt)
        loop.state.step_times.append(dt)
    assert loop.state.mitigations >= 1


# --- power-control integration ----------------------------------------------------

def test_throttled_loop_slows_batch_job_not_uf():
    chassis = ChassisPowerSim(budget_w=260.0)
    chassis.register(JobSpec("serve", cores=16, user_facing=True,
                             p95_util=0.7))
    chassis.register(JobSpec("train", cores=24, user_facing=False,
                             p95_util=1.0))
    utils = np.concatenate([np.full(16, 0.7), np.ones(24)])
    for _ in range(50):
        out = chassis.step(utils)
    assert out["power_w"] <= 260.0 + 1e-6
    f_train = chassis.job_frequency("train")
    f_serve = chassis.job_frequency("serve")
    assert f_serve == pytest.approx(1.0)
    assert f_train < 1.0


class _StubMesh:
    """Mesh stand-in (tests run on ONE real device; the strategy logic
    only needs axis names and sizes)."""
    axis_names = ("data", "model")
    shape = {"data": 2, "model": 2}


def test_sharding_rules_divisible():
    from jax.sharding import PartitionSpec as P
    from repro.launch import sharding as shd
    mesh = _StubMesh()
    strat = shd.make_strategy("fsdp2d", mesh)
    spec = strat.param_spec("layers/attn/wq/w", (4, 64, 128), mesh)
    assert spec == P(None, "data", "model")
    # non-divisible trailing dim loses only that axis
    spec = strat.param_spec("lm_head/w", (64, 51865), mesh)
    assert spec == P("data", None)


def test_constrain_identity_outside_context():
    from repro.launch import sharding as shd
    x = jnp.ones((4, 4))
    assert shd.constrain(x, "residual") is x
