"""End-to-end behaviour tests: the full paper pipeline —
telemetry -> criticality labels -> features -> trained predictor ->
criticality-aware placement -> capping -> oversubscription budget —
and the framework integration (training under the power control plane).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import features as F
from repro.core.criticality import classify
from repro.core.oversubscription import (SCENARIOS, FleetProfile,
                                         compute_budget)
from repro.core.placement import ClusterState, SchedulerPolicy
from repro.core.power_model import ServerPowerModel
from repro.core.predictor import bucket_to_p95, train_service
from repro.sim.telemetry import (generate_chassis_telemetry,
                                 generate_population)


def test_full_paper_pipeline():
    # 1. history: label with the criticality algorithm
    pop = generate_population(900, seed=42)
    hist, arrivals = F.split_history_arrivals(pop)
    hist_labels = np.asarray(classify(jnp.asarray(hist.series)))

    # 2. features + train the prediction service
    aggs = F.subscription_aggregates(hist, hist_labels)
    x_hist = F.build_features(hist, aggs)
    y_hist = hist_labels.astype(np.int64)
    p95_hist = F.p95_bucket(np.array([v.p95_util for v in hist.vms]))
    svc = train_service(x_hist, y_hist, p95_hist, model="rf", n_trees=16)

    # 3. arrivals: query the service, place with Algorithm 1
    x_arr = F.build_features(arrivals, aggs)
    preds = svc.query(x_arr)
    state = ClusterState(n_servers=48, cores_per_server=40,
                         chassis_of_server=np.arange(48) // 12,
                         n_chassis=4)
    policy = SchedulerPolicy(alpha=0.8)
    placed = failures = 0
    for i, vm in enumerate(arrivals.vms):
        uf = bool(preds["workload_type_used"][i])
        p95 = float(bucket_to_p95(preds["p95_bucket_used"][i]))
        srv = policy.choose(state, vm.cores, uf)
        if srv is None:
            failures += 1
            continue
        state.place(srv, vm.cores, p95, uf)
        placed += 1
        if state.free_cores.max() < 32:
            break
    assert placed > 50
    assert failures < placed * 0.2
    # the placement is balanced: chassis scores are tight
    assert np.std(state.score_chassis()) < 0.15

    # 4. oversubscription budget from fleet telemetry
    draws = generate_chassis_telemetry(32, 20, 3720.0, seed=42)
    fleet = FleetProfile(beta=0.4, util_uf=0.65, util_nuf=0.44,
                         allocated_frac=0.85, servers_per_chassis=12,
                         model=ServerPowerModel())
    res = compute_budget(draws.ravel(), 3720.0,
                         SCENARIOS["predictions_minimal_uf_impact"],
                         fleet)
    assert res.oversubscription > 0.05       # meaningful oversubscription
    assert res.uf_event_rate <= 0.001 + 1e-9


def test_training_under_power_cap_converges():
    """The framework integration: a reduced model trains while the
    chassis power controller throttles it (non-user-facing job); loss
    still decreases."""
    from repro.configs import get_config
    from repro.launch.steps import make_train_step
    from repro.models import transformer as T
    from repro.optim import get_optimizer
    from repro.runtime.power_control import (ChassisPowerSim, JobSpec,
                                             ThrottledLoop)

    cfg = get_config("phi4-mini-3.8b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = get_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, impl="naive", lr=1e-3))

    chassis = ChassisPowerSim(budget_w=240.0)
    chassis.register(JobSpec("serve", cores=12, user_facing=True,
                             p95_util=0.6))
    chassis.register(JobSpec("train", cores=28, user_facing=False,
                             p95_util=1.0))
    loop = ThrottledLoop(chassis, "train")

    rng = np.random.default_rng(0)
    # fixed batch: the model memorizes it, so loss must fall
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32)}
    losses, freqs = [], []
    for i in range(12):
        (params, opt_state, m), pw = loop.run_step(
            step, params, opt_state, batch)
        losses.append(float(m["loss"]))
        freqs.append(pw["freq"])
    assert losses[-1] < losses[0]            # training progressed
    assert min(freqs) < 1.0                  # and it WAS throttled
    assert chassis.job_frequency("serve") == pytest.approx(1.0)
