"""`repro.sim.telemetry` arrival-stream generators — edge cases and
per-host split/merge properties (the trace format the cross-host
ingest subsystem consumes, docs/ingest.md)."""
import numpy as np
import pytest

from repro.sim.telemetry import (Population, arrival_stamps,
                                 generate_population, merge_streams,
                                 split_streams, stream_arrivals)


# --- stream_arrivals edge cases -------------------------------------------

def test_stream_arrivals_empty_population_yields_nothing():
    assert list(stream_arrivals(Population(), batch_size=8)) == []


def test_stream_arrivals_batch_larger_than_population():
    pop = generate_population(5, seed=0)
    out = list(stream_arrivals(pop, batch_size=64))
    assert len(out) == 1
    t, batch = out[0]
    assert len(batch) == 5
    assert t > 0.0


def test_stream_arrivals_final_ragged_batch():
    pop = generate_population(10, seed=1)
    out = list(stream_arrivals(pop, batch_size=4))
    assert [len(b) for _, b in out] == [4, 4, 2]
    times = [t for t, _ in out]
    assert all(b > a for a, b in zip(times, times[1:]))
    # the streamed rows cover the population exactly, in order
    subs = np.concatenate([b.subscription for _, b in out])
    np.testing.assert_array_equal(
        subs, [v.subscription for v in pop.vms])


def test_stream_arrivals_poisson_times_increase():
    pop = generate_population(12, seed=2)
    out = list(stream_arrivals(pop, batch_size=4,
                               arrival_rate_per_s=10.0, seed=3))
    times = [t for t, _ in out]
    assert all(b > a for a, b in zip(times, times[1:]))


# --- arrival stamps -------------------------------------------------------

def test_arrival_stamps_strictly_increasing_and_empty():
    assert len(arrival_stamps(0)) == 0
    s = arrival_stamps(32)
    np.testing.assert_array_equal(s, np.arange(1, 33))
    p = arrival_stamps(500, arrival_rate_per_s=1000.0, seed=0)
    assert (np.diff(p) > 0).all()


# --- split/merge ----------------------------------------------------------

@pytest.mark.parametrize("n_hosts,batch_size", [(1, 8), (3, 4), (4, 64)])
def test_split_streams_partitions_population(n_hosts, batch_size):
    pop = generate_population(30, seed=4)
    streams = split_streams(pop, n_hosts, batch_size)
    assert len(streams) == n_hosts
    sizes = [sum(len(b) for _, b in chunks) for chunks in streams]
    assert sum(sizes) == 30
    for chunks in streams:
        for stamps, batch in chunks:
            assert len(stamps) == len(batch) <= batch_size
            assert (np.diff(stamps) > 0).all()


def test_merge_streams_recovers_global_order():
    """The shared clock stamps VM i before VM i+1, so however many
    hosts the population is dealt across, the merged stream is the
    original VM order."""
    pop = generate_population(40, seed=5)
    for n_hosts in (1, 2, 5):
        t, host, merged = merge_streams(
            split_streams(pop, n_hosts, 7, arrival_rate_per_s=100.0,
                          seed=6))
        assert (np.diff(t) > 0).all()
        np.testing.assert_array_equal(
            merged.subscription, [v.subscription for v in pop.vms])
        np.testing.assert_array_equal(
            host, np.arange(40) % n_hosts)


def test_merge_streams_empty():
    t, host, merged = merge_streams([[], []])
    assert len(t) == len(host) == len(merged) == 0
