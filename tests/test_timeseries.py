import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import timeseries as ts


def test_rolling_day_mean_matches_naive():
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 100, (3, 240)).astype(np.float32)
    out = np.asarray(ts.rolling_day_mean(jnp.asarray(x)))
    for t in range(240):
        lo = max(t - 47, 0)
        expect = x[:, lo:t + 1].mean(-1)
        np.testing.assert_allclose(out[:, t], expect, rtol=2e-5)


def test_detrend_removes_exponential_trend():
    slots = np.arange(240)
    base = np.tile(10 + 5 * np.sin(2 * np.pi * slots / 48), (1, 1))
    trended = base * np.exp(0.05 * slots / 48)
    flat = np.asarray(ts.detrend(jnp.asarray(trended.astype(np.float32))))
    # after detrending, day-over-day drift of the mean is small
    daily = flat.reshape(1, 5, 48).mean(-1)[0]
    assert daily[1:].std() < 0.05 * daily[1:].mean()


def test_template_extraction_recovers_period():
    slots = np.arange(240)
    pattern = np.sin(2 * np.pi * slots / 48)
    x = jnp.asarray((pattern + 0.01)[None].astype(np.float32))
    tmpl = np.asarray(ts.extract_template(x, 48))[0]
    np.testing.assert_allclose(tmpl, pattern[:48] + 0.01, atol=1e-5)


def test_template_deviation_zero_for_perfectly_periodic():
    slots = np.arange(240)
    x = jnp.asarray((5 + np.sin(2 * np.pi * slots / 48))[None]
                    .astype(np.float32))
    dev = float(ts.template_deviation(x, 48)[0])
    assert dev < 1e-5


@given(hnp.arrays(np.float32, (2, 240),
                  elements=st.floats(0, 100, width=32)))
def test_preprocess_finite(x):
    out = np.asarray(ts.preprocess(jnp.asarray(x)))
    assert np.isfinite(out).all()


@given(st.integers(0, 1000))
def test_deviation_nonnegative_and_keeps_order(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 100, (1, 240)).astype(np.float32))
    for period in (48, 24, 16):
        d = float(ts.template_deviation(x, period)[0])
        assert d >= 0.0
        assert np.isfinite(d)


def test_template_deviation_trims_outliers():
    slots = np.arange(240)
    clean = 5 + np.sin(2 * np.pi * slots / 48)
    dirty = clean.copy()
    dirty[10:40] = 50.0               # large interruption (<20% of series)
    d_clean = float(ts.template_deviation(
        jnp.asarray(clean[None].astype(np.float32)), 48)[0])
    d_dirty = float(ts.template_deviation(
        jnp.asarray(dirty[None].astype(np.float32)), 48)[0])
    # trimming keeps the deviation bounded despite the interruption
    assert d_dirty < 10 * (d_clean + 0.1)
